//! Column-major dense matrix.
//!
//! Column-major is deliberate: it matches the paper's §IV-A storage layout
//! (the mode-1 matricization of a column-major tensor is a no-op view) and
//! the column-major convention of cuBLAS/XLA literals.

use crate::util::rng::Xoshiro256;
use std::fmt;

/// Dense `rows × cols` matrix of `f32`, column-major (`data[i + j*rows]`).
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    // ---------- constructors ----------

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Takes ownership of a column-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Builds from a row-major nested-slice literal (test convenience).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m.set(i, j, v);
            }
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    /// i.i.d. standard-normal entries.
    pub fn random_normal(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Self {
        let mut data = vec![0.0f32; rows * cols];
        rng.fill_gaussian_f32(&mut data);
        Self { rows, cols, data }
    }

    // ---------- shape & element access ----------

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows] = v;
    }

    #[inline]
    pub fn add_assign_at(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows] += v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Column `j` as a contiguous slice (free in column-major).
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Copies row `i` out (strided access).
    pub fn row(&self, i: usize) -> Vec<f32> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    // ---------- submatrices ----------

    /// Rows `r0..r1` (copy).  Column-major means each column's row range
    /// is one contiguous segment — copied with `copy_from_slice`, which
    /// matters on the hot paths that strip-split by rows (parallel GEMM,
    /// streaming refinement factor slices).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        let sub_rows = r1 - r0;
        let mut data = vec![0.0f32; sub_rows * self.cols];
        for j in 0..self.cols {
            let src = &self.data[j * self.rows + r0..j * self.rows + r1];
            data[j * sub_rows..(j + 1) * sub_rows].copy_from_slice(src);
        }
        Matrix {
            rows: sub_rows,
            cols: self.cols,
            data,
        }
    }

    /// Columns `c0..c1` (cheap memcpy in column-major).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        Matrix {
            rows: self.rows,
            cols: c1 - c0,
            data: self.data[c0 * self.rows..c1 * self.rows].to_vec(),
        }
    }

    /// Writes `block` into `self` at row/col offset.
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for j in 0..block.cols {
            let src = block.col(j);
            let dst_off = r0 + (c0 + j) * self.rows;
            self.data[dst_off..dst_off + block.rows].copy_from_slice(src);
        }
    }

    /// Stacks matrices vertically (all must share `cols`).
    pub fn vstack(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        let rows: usize = parts.iter().map(|m| m.rows).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut r = 0;
        for m in parts {
            assert_eq!(m.cols, cols, "vstack: column mismatch");
            out.set_block(r, 0, m);
            r += m.rows;
        }
        out
    }

    // ---------- elementwise & norms ----------

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// `‖self − other‖_F / ‖other‖_F` (0 denominator → absolute norm).
    pub fn rel_error(&self, other: &Matrix) -> f64 {
        let denom = other.frobenius_norm();
        let diff = self.sub(other).frobenius_norm();
        if denom == 0.0 {
            diff
        } else {
            diff / denom
        }
    }

    /// Per-column L2 norms.
    pub fn col_norms(&self) -> Vec<f32> {
        (0..self.cols)
            .map(|j| self.col(j).iter().map(|&x| x * x).sum::<f32>().sqrt())
            .collect()
    }

    /// Normalizes each column to unit L2 norm, returning the norms.
    /// Zero columns are left untouched (norm reported as 0).
    pub fn normalize_cols(&mut self) -> Vec<f32> {
        let norms = self.col_norms();
        for (j, &n) in norms.iter().enumerate() {
            if n > 0.0 {
                for x in self.col_mut(j) {
                    *x /= n;
                }
            }
        }
        norms
    }

    /// Applies a column permutation: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.cols);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for (j, &src) in perm.iter().enumerate() {
            out.col_mut(j).copy_from_slice(self.col(src));
        }
        out
    }

    /// Multiplies column `j` by `scales[j]`.
    pub fn scale_cols(&self, scales: &[f32]) -> Matrix {
        assert_eq!(scales.len(), self.cols);
        let mut out = self.clone();
        for (j, &s) in scales.iter().enumerate() {
            for x in out.col_mut(j) {
                *x *= s;
            }
        }
        out
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(6);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_column_major() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.data(), &[1.0, 3.0, 2.0, 4.0]);
        assert_eq!(m.col(1), &[2.0, 4.0]);
        assert_eq!(m.row(1), vec![3.0, 4.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let m = Matrix::random_normal(7, 4, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn identity_and_norms() {
        let i3 = Matrix::identity(3);
        assert_eq!(i3.get(1, 1), 1.0);
        assert_eq!(i3.get(0, 2), 0.0);
        assert!((i3.frobenius_norm() - 3f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn slicing_and_stacking() {
        let m = Matrix::from_rows(&[&[1., 2.], &[3., 4.], &[5., 6.]]);
        let top = m.slice_rows(0, 1);
        assert_eq!(top.row(0), vec![1.0, 2.0]);
        let right = m.slice_cols(1, 2);
        assert_eq!(right.col(0), &[2.0, 4.0, 6.0]);
        let stacked = Matrix::vstack(&[&top, &m.slice_rows(1, 3)]);
        assert_eq!(stacked, m);
    }

    #[test]
    fn set_block_roundtrip() {
        let mut big = Matrix::zeros(4, 4);
        let small = Matrix::from_rows(&[&[1., 2.], &[3., 4.]]);
        big.set_block(1, 2, &small);
        assert_eq!(big.get(1, 2), 1.0);
        assert_eq!(big.get(2, 3), 4.0);
        assert_eq!(big.get(0, 0), 0.0);
    }

    #[test]
    fn normalize_and_rescale_cols() {
        let mut m = Matrix::from_rows(&[&[3.0, 0.0], &[4.0, 0.0]]);
        let norms = m.normalize_cols();
        assert!((norms[0] - 5.0).abs() < 1e-6);
        assert_eq!(norms[1], 0.0); // zero column untouched
        assert!((m.get(0, 0) - 0.6).abs() < 1e-6);
        let back = m.scale_cols(&norms);
        assert!((back.get(1, 0) - 4.0).abs() < 1e-5);
    }

    #[test]
    fn permute_cols_is_permutation() {
        let m = Matrix::from_rows(&[&[1., 2., 3.], &[4., 5., 6.]]);
        let p = m.permute_cols(&[2, 0, 1]);
        assert_eq!(p.col(0), &[3.0, 6.0]);
        assert_eq!(p.col(1), &[1.0, 4.0]);
        assert_eq!(p.col(2), &[2.0, 5.0]);
    }

    #[test]
    fn rel_error_zero_for_identical() {
        let m = Matrix::from_rows(&[&[1., 2.], &[3., 4.]]);
        assert_eq!(m.rel_error(&m), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_bad_len() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 3]);
    }
}
