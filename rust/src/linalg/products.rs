//! Structured matrix products of CP decomposition: Khatri-Rao (column-wise
//! Kronecker, `⊙`), Kronecker (`⊗`), and Hadamard (`*`).
//!
//! The ALS identity `(C ⊙ B)ᵀ (C ⊙ B) = CᵀC * BᵀB` (Hadamard of Grams) is
//! what lets Alg. 1 avoid ever forming the `JK × R` Khatri-Rao product for
//! the Gram side; the MTTKRP side is computed blocked.

use super::matrix::Matrix;

/// Khatri-Rao product `A ⊙ B` for `A (I×R)`, `B (J×R)` → `(I·J) × R`,
/// with the *column-major / mode-product convention*: row index is
/// `j·I + i`?  No — we use the convention matching the unfoldings in
/// `tensor::unfold`: `(A ⊙ B)[i + j*I, r] = A[i,r] · B[j,r]` would pair with
/// row-major unfoldings; our column-major mode-1 unfolding
/// `X_(1) (I × J·K)` pairs columns as `j + k·J`, i.e.
/// `X_(1) ≈ A (C ⊙ B)ᵀ` with `(C ⊙ B)[j + k*J, r] = C[k,r]·B[j,r]`.
/// So `khatri_rao(C, B)` returns the matrix whose row `j + k·J` is
/// `C[k,:] * B[j,:]` — the *first* argument varies slowest.
///
/// **Role:** test oracle.  Production MTTKRPs use the fused kernel
/// (`linalg::matmul::mttkrp_fused`), which synthesizes these entries
/// directly into packed GEMM panels; materializing the `(J·K)×R` product
/// is exactly the memory wall the fused path removes, so this function
/// survives for the differential tests (via
/// `linalg::backend::mttkrp_materialized`) and the Gram-identity property
/// checks only.
pub fn khatri_rao(slow: &Matrix, fast: &Matrix) -> Matrix {
    let r = slow.cols();
    assert_eq!(fast.cols(), r, "khatri_rao: rank mismatch");
    let k_dim = slow.rows();
    let j_dim = fast.rows();
    // Built straight into the column-major buffer with
    // `with_capacity`/`extend` — no zero-fill pass that every entry then
    // overwrites.
    let mut data = Vec::with_capacity(j_dim * k_dim * r);
    for c in 0..r {
        let f_col = fast.col(c);
        for &sv in slow.col(c) {
            data.extend(f_col.iter().map(|&fv| sv * fv));
        }
    }
    Matrix::from_vec(j_dim * k_dim, r, data)
}

/// Kronecker product `A ⊗ B` for `A (m×n)`, `B (p×q)` → `(m·p) × (n·q)`,
/// with block `(i,j)` equal to `A[i,j]·B`.
pub fn kronecker(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = (a.rows(), a.cols());
    let (p, q) = (b.rows(), b.cols());
    let mut out = Matrix::zeros(m * p, n * q);
    for j in 0..n {
        for i in 0..m {
            let aij = a.get(i, j);
            if aij == 0.0 {
                continue;
            }
            for jj in 0..q {
                for ii in 0..p {
                    out.set(i * p + ii, j * q + jj, aij * b.get(ii, jj));
                }
            }
        }
    }
    out
}

/// Elementwise (Hadamard) product `A * B`.
pub fn hadamard(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "hadamard: shape mismatch");
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| x * y)
        .collect();
    Matrix::from_vec(a.rows(), a.cols(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, Trans};
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn khatri_rao_small() {
        let c = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]); // K=2, R=2
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]); // J=2
        let kr = khatri_rao(&c, &b); // rows: j + k*J
        assert_eq!((kr.rows(), kr.cols()), (4, 2));
        // row (j=0,k=0) = C[0,:]*B[0,:] = [5, 12]
        assert_eq!(kr.row(0), vec![5.0, 12.0]);
        // row (j=1,k=0) = C[0,:]*B[1,:] = [7, 16]
        assert_eq!(kr.row(1), vec![7.0, 16.0]);
        // row (j=0,k=1) = C[1,:]*B[0,:] = [15, 24]
        assert_eq!(kr.row(2), vec![15.0, 24.0]);
        assert_eq!(kr.row(3), vec![21.0, 32.0]);
    }

    #[test]
    fn kronecker_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[0.0, 3.0], &[4.0, 5.0]]);
        let k = kronecker(&a, &b);
        assert_eq!((k.rows(), k.cols()), (2, 4));
        assert_eq!(k.row(0), vec![0.0, 3.0, 0.0, 6.0]);
        assert_eq!(k.row(1), vec![4.0, 5.0, 8.0, 10.0]);
    }

    #[test]
    fn gram_identity_property() {
        // (A ⊙ B)ᵀ(A ⊙ B) == (AᵀA) * (BᵀB) — the identity ALS relies on.
        prop::check("khatri-rao-gram", 25, |g| {
            let r = g.int(1, 4);
            let i = g.int(1, 6);
            let j = g.int(1, 6);
            let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1_000_000) as u64);
            let a = Matrix::random_normal(i, r, &mut rng);
            let b = Matrix::random_normal(j, r, &mut rng);
            let kr = khatri_rao(&a, &b);
            let lhs = matmul(&kr, Trans::Yes, &kr, Trans::No);
            let rhs = hadamard(
                &matmul(&a, Trans::Yes, &a, Trans::No),
                &matmul(&b, Trans::Yes, &b, Trans::No),
            );
            assert!(lhs.rel_error(&rhs) < 1e-4, "err={}", lhs.rel_error(&rhs));
        });
    }

    #[test]
    fn khatri_rao_is_kron_columns() {
        // Column r of A ⊙ B equals kron(a_r, b_r).
        let mut rng = Xoshiro256::seed_from_u64(31);
        let a = Matrix::random_normal(3, 2, &mut rng);
        let b = Matrix::random_normal(4, 2, &mut rng);
        let kr = khatri_rao(&a, &b);
        for r in 0..2 {
            let ar = Matrix::from_vec(3, 1, a.col(r).to_vec());
            let br = Matrix::from_vec(4, 1, b.col(r).to_vec());
            let k = kronecker(&ar, &br);
            for idx in 0..12 {
                assert!((kr.get(idx, r) - k.get(idx, 0)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn hadamard_commutes() {
        let mut rng = Xoshiro256::seed_from_u64(32);
        let a = Matrix::random_normal(5, 5, &mut rng);
        let b = Matrix::random_normal(5, 5, &mut rng);
        assert_eq!(hadamard(&a, &b), hadamard(&b, &a));
    }

    #[test]
    #[should_panic(expected = "rank mismatch")]
    fn khatri_rao_rank_mismatch() {
        let _ = khatri_rao(&Matrix::zeros(2, 2), &Matrix::zeros(2, 3));
    }
}
