//! The **`ComputeBackend`** dispatch surface — one trait for every dense
//! kernel the compressed-CP pipeline is hot in.
//!
//! The paper's scalability argument (and the randomized-CP literature it
//! builds on) rests on pushing all work into a handful of dense
//! contractions: GEMM for the blocked TTM chain, MTTKRP for the ALS
//! sweeps, Gram matrices for the tiny `R×R` solves, and batched small
//! GEMMs for the per-block compression contractions.  This module
//! abstracts exactly that surface once so every layer above `linalg`
//! (`cp`, `compress`, `coordinator`, `apps`) dispatches through a backend
//! handle instead of calling free functions:
//!
//! * [`SerialBackend`] — the cache-blocked single-threaded kernels in
//!   [`super::matmul`]; the differential-test reference and the paper's
//!   "Baseline (CPU)" arm.
//! * [`CpuParallelBackend`] — the same micro-kernel repartitioned over
//!   [`ThreadPool`]: GEMMs split into row/column macro-strips (each worker
//!   packs its own panels), fused MTTKRPs split by slow-factor panels or
//!   output rows (see below), and `gemm_batch` fanned out item-per-worker.
//!   This is the "Parallel on CPU" arm.
//! * `runtime::XlaBackend` — implements the same trait, delegating the
//!   dense kernels to a CPU backend while exposing the fused AOT Pallas
//!   artifacts through the [`ComputeBackend::block_compressor`] /
//!   [`ComputeBackend::proxy_decomposer`] stage hooks ("Parallel on GPU",
//!   adapted to the MXU).
//!
//! Strip splitting preserves the serial kernel's `KC`-panel accumulation
//! order, so parallel results match the serial reference to float
//! round-off (bitwise-identical when strip widths align with the
//! micro-kernel's `NR`-column register tiling) — the differential tests in
//! `rust/tests/backend_differential.rs` hold to well below `1e-4`.
//!
//! ## Fused MTTKRP dataflow
//!
//! [`ComputeBackend::mttkrp`] defaults to the **fused zero-materialization
//! kernel** ([`matmul::mttkrp_fused`]): the Khatri-Rao operand is
//! synthesized straight into the packed `KC×NC` B-panels, so no `(J·K)×R`
//! intermediate is ever allocated — the memory win the paper's scalability
//! claim rests on.  [`CpuParallelBackend`] splits the fused kernel two
//! ways, both built on [`matmul::mttkrp_fused_acc`]'s exact splitting
//! invariant:
//!
//! * **panel split** (default when the slow factor has enough rows): each
//!   [`ThreadPool::for_each_chunk`] chunk streams a contiguous range of
//!   slow-factor panels — a contiguous byte range of the unfolding — into a
//!   per-chunk `I×R` accumulator, merged once under a lock;
//! * **row split** (tall outputs with a short slow factor): workers own
//!   disjoint output row strips, stitched together with no merge reduction.
//!
//! The materialized `khatri_rao`+GEMM formulation survives only as
//! [`mttkrp_materialized`], the differential-test oracle.  The Gram of the
//! (never-formed) Khatri-Rao operand comes from
//! [`ComputeBackend::kr_gram`] via the Hadamard-of-Grams identity.

use super::matmul::{self, Trans};
use super::matrix::Matrix;
use super::products::{hadamard, khatri_rao};
use crate::util::threadpool::ThreadPool;
use std::sync::{Arc, Mutex};

/// Shape of `op(M)`.
#[inline]
fn op_dims(m: &Matrix, t: Trans) -> (usize, usize) {
    match t {
        Trans::No => (m.rows(), m.cols()),
        Trans::Yes => (m.cols(), m.rows()),
    }
}

/// One dispatch surface for the pipeline's dense kernels.
///
/// Provided methods ([`matmul`](ComputeBackend::matmul),
/// [`gram`](ComputeBackend::gram), [`mttkrp`](ComputeBackend::mttkrp),
/// [`gemm_batch`](ComputeBackend::gemm_batch)) are built on
/// [`gemm`](ComputeBackend::gemm), so a minimal backend only implements
/// `gemm` + `name` and inherits correct (serial-composed) versions of the
/// rest; backends override them when they can do better (parallel fan-out,
/// fused device kernels).
pub trait ComputeBackend: Send + Sync {
    /// Human-readable backend name (metrics/logs).
    fn name(&self) -> &'static str;

    /// `C ← alpha · op(A)·op(B) + beta · C` — the root kernel.
    ///
    /// Semantics match [`matmul::gemm`]: `beta = 0` clears `C` (including
    /// NaNs) before accumulating.  Panics on shape mismatch.
    fn gemm(
        &self,
        alpha: f32,
        a: &Matrix,
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c: &mut Matrix,
    );

    /// Batched GEMM sharing one right-hand operand:
    /// `C_i ← alpha · op(A_i)·op(B) + beta · C_i` for every `i`.
    ///
    /// This is the shape of the per-block compression contractions (the
    /// mode-2 slice loop of the unfold-free TTM chain): many small left
    /// operands against a single compression-matrix slice.
    fn gemm_batch(
        &self,
        alpha: f32,
        a_list: &[Matrix],
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c_list: &mut [Matrix],
    ) {
        assert_eq!(a_list.len(), c_list.len(), "gemm_batch: batch size mismatch");
        for (a, c) in a_list.iter().zip(c_list.iter_mut()) {
            self.gemm(alpha, a, op_a, b, op_b, beta, c);
        }
    }

    /// Convenience: `op(A)·op(B)` into a fresh matrix.
    fn matmul(&self, a: &Matrix, op_a: Trans, b: &Matrix, op_b: Trans) -> Matrix {
        let (m, _) = op_dims(a, op_a);
        let (_, n) = op_dims(b, op_b);
        let mut c = Matrix::zeros(m, n);
        self.gemm(1.0, a, op_a, b, op_b, 0.0, &mut c);
        c
    }

    /// `y ← op(A)·x` (cheap; serial on every CPU backend).
    fn matvec(&self, a: &Matrix, op: Trans, x: &[f32]) -> Vec<f32> {
        matmul::matvec(a, op, x)
    }

    /// Gram matrix `FᵀF` of a factor (`R×R`, the ALS normal-equation
    /// operand).
    fn gram(&self, f: &Matrix) -> Matrix {
        self.matmul(f, Trans::Yes, f, Trans::No)
    }

    /// MTTKRP for `mode`: `X_(mode) · (slow ⊙ fast)` with the crate's
    /// unfolding/Khatri-Rao convention (`khatri_rao(slow, fast)` pairs row
    /// `fast + slow·dim_fast`, matching `tensor::unfold`).
    ///
    /// `x_mode` is the mode-`mode` unfolding (`dims[mode-1] × rest`); the
    /// result is `dims[mode-1] × R`.  `mode` is carried for assertions and
    /// diagnostics — the contraction itself is fully determined by the
    /// operands.
    ///
    /// The default is the **fused** kernel ([`matmul::mttkrp_fused`]): the
    /// Khatri-Rao product is never materialized — its entries exist only
    /// inside the packed `KC×NC` panels of the blocked GEMM.  The
    /// materialized formulation survives as [`mttkrp_materialized`], the
    /// differential-test oracle.
    fn mttkrp(&self, mode: usize, x_mode: &Matrix, slow: &Matrix, fast: &Matrix) -> Matrix {
        validate_mttkrp(mode, x_mode, slow, fast);
        matmul::mttkrp_fused(x_mode, slow, fast)
    }

    /// Gram `(slow ⊙ fast)ᵀ(slow ⊙ fast)` of the *implicit* Khatri-Rao
    /// operand via the Hadamard-of-Grams identity
    /// `(C ⊙ B)ᵀ(C ⊙ B) = CᵀC * BᵀB` (proven in `linalg::products`) —
    /// `R×R` work on two factor Grams, never the `(J·K)×R` product.  This
    /// is the Gram-side twin of the fused [`mttkrp`](ComputeBackend::mttkrp):
    /// together they make a full ALS normal equation Khatri-Rao-free.
    fn kr_gram(&self, slow: &Matrix, fast: &Matrix) -> Matrix {
        hadamard(&self.gram(slow), &self.gram(fast))
    }

    /// Fans `n` **independent** work items out across the backend's
    /// residency: `f(i)` runs exactly once for every `i in 0..n`.  This is
    /// the batched-ALS sweep's coalescing primitive — one pool scope (one
    /// thread wake-up) covers a whole batch of small decompositions instead
    /// of each job paying its own.  Items must not depend on each other:
    /// the serial default runs them in index order, parallel backends in
    /// any order — item-local results are identical either way, which is
    /// what the batch lane's bitwise-identity guarantee rests on.
    fn for_each_item(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            f(i);
        }
    }

    /// Stage hook: a backend owning a fused block-compression kernel (the
    /// XLA `ttm_chain` artifact) exposes it here; CPU backends return
    /// `None` and the pipeline composes the generic chain from `gemm`.
    fn block_compressor(&self) -> Option<&dyn crate::compress::BlockCompressor> {
        None
    }

    /// Stage hook: a backend owning a fused proxy-ALS kernel (the XLA
    /// `als_sweep` artifact) exposes it here; CPU backends return `None`
    /// and the pipeline runs the in-crate rust ALS.
    fn proxy_decomposer(&self) -> Option<&dyn crate::coordinator::ProxyDecomposer> {
        None
    }
}

/// Shared MTTKRP operand validation (trait default + parallel override).
fn validate_mttkrp(mode: usize, x_mode: &Matrix, slow: &Matrix, fast: &Matrix) {
    assert!((1..=3).contains(&mode), "mttkrp: mode must be 1..=3, got {mode}");
    assert_eq!(
        x_mode.cols(),
        slow.rows() * fast.rows(),
        "mttkrp mode {mode}: unfolding has {} columns but slow×fast = {}×{}",
        x_mode.cols(),
        slow.rows(),
        fast.rows()
    );
}

/// Reference MTTKRP that **materializes** the `(J·K)×R` Khatri-Rao product
/// before a single GEMM — the formulation the fused kernel replaced.  Kept
/// solely as the differential-test oracle and the `materialized` arm of the
/// `gemm_mttkrp` bench; production paths must not call it (the buffer it
/// allocates is exactly the memory wall the fused path removes).
pub fn mttkrp_materialized(x_mode: &Matrix, slow: &Matrix, fast: &Matrix) -> Matrix {
    let kr = khatri_rao(slow, fast);
    matmul::matmul(x_mode, Trans::No, &kr, Trans::No)
}

/// Single-threaded reference backend: thin forwarding to the cache-blocked
/// kernels in [`matmul`].  Every other backend is differential-tested
/// against this one.
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialBackend;

impl ComputeBackend for SerialBackend {
    fn name(&self) -> &'static str {
        "cpu-serial"
    }

    fn gemm(
        &self,
        alpha: f32,
        a: &Matrix,
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c: &mut Matrix,
    ) {
        matmul::gemm(alpha, a, op_a, b, op_b, beta, c);
    }
}

/// Below this many FLOPs (`2·m·n·k`) a GEMM runs serially: a pool scope
/// spawns OS threads, which only pays for itself on macroscopic tiles.
const DEFAULT_PAR_MIN_FLOPS: usize = 1 << 22;

/// Multi-threaded CPU backend: the serial micro-kernel repartitioned over
/// a [`ThreadPool`].
///
/// * Wide outputs (`n ≥ m`) split into contiguous **column strips** — free
///   to extract and scatter in column-major storage.
/// * Tall outputs (the MTTKRP shape: `I × R` with huge inner `k`) split
///   into **row strips** of the unfolding, each worker running the blocked
///   kernel on its chunk with its own packed panels.
/// * `gemm_batch` fans the (independent) batch items out across workers.
///
/// Tiny problems fall back to the serial path (see
/// [`CpuParallelBackend::with_min_par_flops`]); nested use inside
/// block-level pool jobs should hold a [`SerialBackend`] instead — the
/// pipeline's streaming stages do exactly that (block-level parallelism
/// only).
pub struct CpuParallelBackend {
    pool: ThreadPool,
    min_par_flops: usize,
}

impl CpuParallelBackend {
    /// Backend over `threads.max(1)` workers.
    pub fn new(threads: usize) -> Self {
        Self {
            pool: ThreadPool::new(threads),
            min_par_flops: DEFAULT_PAR_MIN_FLOPS,
        }
    }

    /// Sized by [`crate::util::default_threads`].
    pub fn default_sized() -> Self {
        Self::new(crate::util::default_threads())
    }

    /// Overrides the serial-fallback threshold (`0` forces the parallel
    /// path — used by the differential tests to exercise it on small
    /// shapes).
    pub fn with_min_par_flops(mut self, flops: usize) -> Self {
        self.min_par_flops = flops;
        self
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }
}

impl ComputeBackend for CpuParallelBackend {
    fn name(&self) -> &'static str {
        "cpu-parallel"
    }

    fn gemm(
        &self,
        alpha: f32,
        a: &Matrix,
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c: &mut Matrix,
    ) {
        let (m, k) = op_dims(a, op_a);
        let (k2, n) = op_dims(b, op_b);
        assert_eq!(k, k2, "gemm: inner dimension mismatch ({k} vs {k2})");
        assert_eq!((c.rows(), c.cols()), (m, n), "gemm: output shape mismatch");

        let flops = 2usize
            .saturating_mul(m)
            .saturating_mul(n)
            .saturating_mul(k);
        let threads = self.pool.size();
        if threads == 1 || alpha == 0.0 || flops < self.min_par_flops {
            matmul::gemm(alpha, a, op_a, b, op_b, beta, c);
            return;
        }

        if n >= m {
            // Column strips: op(B) columns j0..j1 and the matching C strip.
            let strips = ThreadPool::partition(n, threads);
            let c_ref: &Matrix = c;
            let parts = self.pool.map_indexed(strips.len(), |s| {
                let (j0, j1) = strips[s];
                let b_sub = match op_b {
                    Trans::No => b.slice_cols(j0, j1),
                    Trans::Yes => b.slice_rows(j0, j1),
                };
                let mut c_sub = c_ref.slice_cols(j0, j1);
                matmul::gemm(alpha, a, op_a, &b_sub, op_b, beta, &mut c_sub);
                c_sub
            });
            for (s, part) in parts.iter().enumerate() {
                c.set_block(0, strips[s].0, part);
            }
        } else {
            // Row strips: op(A) rows i0..i1 and the matching C strip.
            let strips = ThreadPool::partition(m, threads);
            let c_ref: &Matrix = c;
            let parts = self.pool.map_indexed(strips.len(), |s| {
                let (i0, i1) = strips[s];
                let a_sub = match op_a {
                    Trans::No => a.slice_rows(i0, i1),
                    Trans::Yes => a.slice_cols(i0, i1),
                };
                let mut c_sub = c_ref.slice_rows(i0, i1);
                matmul::gemm(alpha, &a_sub, op_a, b, op_b, beta, &mut c_sub);
                c_sub
            });
            for (s, part) in parts.iter().enumerate() {
                c.set_block(strips[s].0, 0, part);
            }
        }
    }

    /// Fused MTTKRP, split over the pool two ways (both exact: they
    /// partition [`matmul::mttkrp_fused_acc`]'s accumulation ranges):
    ///
    /// * **panel split** when the slow factor is deep enough — each chunk
    ///   of slow-factor rows covers a contiguous column (and byte) range of
    ///   the unfolding; per-chunk `I×R` accumulators merge once under a
    ///   lock (`O(I·R)` per chunk, tiny next to the streamed panel work);
    /// * **row split** otherwise — workers own disjoint output row strips,
    ///   each streaming every panel of its strip, stitched with
    ///   `set_block` (no reduction).
    fn mttkrp(&self, mode: usize, x_mode: &Matrix, slow: &Matrix, fast: &Matrix) -> Matrix {
        validate_mttkrp(mode, x_mode, slow, fast);
        let (i, r) = (x_mode.rows(), fast.cols());
        let kdim = slow.rows();
        let flops = 2usize
            .saturating_mul(i)
            .saturating_mul(x_mode.cols())
            .saturating_mul(r);
        let threads = self.pool.size();
        if threads == 1 || flops < self.min_par_flops {
            return matmul::mttkrp_fused(x_mode, slow, fast);
        }
        if kdim >= 2 * threads || kdim > i {
            let acc = Mutex::new(Matrix::zeros(i, r));
            self.pool.for_each_chunk(kdim, 1, |panels| {
                let mut part = Matrix::zeros(i, r);
                matmul::mttkrp_fused_acc(x_mode, 0..i, panels, slow, fast, &mut part);
                let mut merged = acc.lock().unwrap();
                for c in 0..r {
                    for (dst, &src) in merged.col_mut(c).iter_mut().zip(part.col(c)) {
                        *dst += src;
                    }
                }
            });
            acc.into_inner().unwrap()
        } else {
            let strips = ThreadPool::partition(i, threads);
            let parts = self.pool.map_indexed(strips.len(), |s| {
                let (i0, i1) = strips[s];
                let mut part = Matrix::zeros(i1 - i0, r);
                matmul::mttkrp_fused_acc(x_mode, i0..i1, 0..kdim, slow, fast, &mut part);
                part
            });
            let mut out = Matrix::zeros(i, r);
            for (s, part) in parts.iter().enumerate() {
                out.set_block(strips[s].0, 0, part);
            }
            out
        }
    }

    fn gemm_batch(
        &self,
        alpha: f32,
        a_list: &[Matrix],
        op_a: Trans,
        b: &Matrix,
        op_b: Trans,
        beta: f32,
        c_list: &mut [Matrix],
    ) {
        assert_eq!(a_list.len(), c_list.len(), "gemm_batch: batch size mismatch");
        // Serial fallback mirrors `gemm`: spawning a pool scope only pays
        // for itself when the whole batch carries macroscopic work.
        let (k_b, n_b) = op_dims(b, op_b);
        let batch_flops: usize = a_list
            .iter()
            .map(|a| {
                let (m, _) = op_dims(a, op_a);
                2usize
                    .saturating_mul(m)
                    .saturating_mul(k_b)
                    .saturating_mul(n_b)
            })
            .sum();
        if self.pool.size() == 1 || a_list.len() <= 1 || batch_flops < self.min_par_flops {
            for (a, c) in a_list.iter().zip(c_list.iter_mut()) {
                matmul::gemm(alpha, a, op_a, b, op_b, beta, c);
            }
            return;
        }
        // Independent items: one pool job each, serial kernel inside.
        self.pool.scope(|scope| {
            for (a, c) in a_list.iter().zip(c_list.iter_mut()) {
                scope.spawn(move || matmul::gemm(alpha, a, op_a, b, op_b, beta, c));
            }
        });
    }

    /// One pool scope for the whole batch: items drain the shared queue
    /// across the pool's workers, so each worker's thread-local pack arena
    /// is reused across every item it picks up.
    fn for_each_item(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.pool.size() == 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.pool.scope(|scope| {
            for i in 0..n {
                scope.spawn(move || f(i));
            }
        });
    }
}

/// Backend handle threaded through the pipeline stages.
pub type BackendHandle = Arc<dyn ComputeBackend>;

/// The serial reference backend as a shared handle.
pub fn serial_backend() -> BackendHandle {
    Arc::new(SerialBackend)
}

/// A CPU backend handle: serial for `threads ≤ 1`, parallel otherwise.
pub fn cpu_backend(threads: usize) -> BackendHandle {
    if threads <= 1 {
        Arc::new(SerialBackend)
    } else {
        Arc::new(CpuParallelBackend::new(threads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::gemm_naive;
    use crate::util::rng::Xoshiro256;

    fn close(a: &Matrix, b: &Matrix, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        let err = a.rel_error(b);
        assert!(err < tol, "rel error {err} > {tol}");
    }

    fn par() -> CpuParallelBackend {
        // Threshold 0 forces the strip-split path even on tiny shapes.
        CpuParallelBackend::new(4).with_min_par_flops(0)
    }

    #[test]
    fn parallel_gemm_matches_naive_all_transposes() {
        let mut rng = Xoshiro256::seed_from_u64(900);
        let be = par();
        for &(m, k, n) in &[(5, 7, 9), (64, 32, 48), (130, 33, 257), (257, 129, 3)] {
            for &op_a in &[Trans::No, Trans::Yes] {
                for &op_b in &[Trans::No, Trans::Yes] {
                    let (ar, ac) = if op_a == Trans::No { (m, k) } else { (k, m) };
                    let (br, bc) = if op_b == Trans::No { (k, n) } else { (n, k) };
                    let a = Matrix::random_normal(ar, ac, &mut rng);
                    let b = Matrix::random_normal(br, bc, &mut rng);
                    let fast = be.matmul(&a, op_a, &b, op_b);
                    let slow = gemm_naive(&a, op_a, &b, op_b);
                    close(&fast, &slow, 1e-4);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise_on_aligned_tiles() {
        // n = 256 over 4 workers → 64-wide strips, a multiple of the
        // micro-kernel's NR-column register tiling, and k < KC keeps a
        // single accumulation panel: identical floats.
        let mut rng = Xoshiro256::seed_from_u64(901);
        let a = Matrix::random_normal(150, 70, &mut rng);
        let b = Matrix::random_normal(70, 256, &mut rng);
        let serial = SerialBackend.matmul(&a, Trans::No, &b, Trans::No);
        let parallel = par().matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn alpha_beta_accumulate_semantics() {
        let mut rng = Xoshiro256::seed_from_u64(902);
        let be = par();
        let a = Matrix::random_normal(40, 20, &mut rng);
        let b = Matrix::random_normal(20, 50, &mut rng);
        let c0 = Matrix::random_normal(40, 50, &mut rng);
        let mut c_par = c0.clone();
        be.gemm(0.5, &a, Trans::No, &b, Trans::No, 2.0, &mut c_par);
        let mut c_ser = c0.clone();
        matmul::gemm(0.5, &a, Trans::No, &b, Trans::No, 2.0, &mut c_ser);
        close(&c_par, &c_ser, 1e-6);
    }

    #[test]
    fn beta_zero_clears_nan_in_parallel_path() {
        let a = Matrix::identity(33);
        let mut c = Matrix::from_vec(33, 33, vec![f32::NAN; 33 * 33]);
        par().gemm(1.0, &a, Trans::No, &a, Trans::No, 0.0, &mut c);
        assert_eq!(c, Matrix::identity(33));
    }

    #[test]
    fn gemm_batch_matches_loop() {
        let mut rng = Xoshiro256::seed_from_u64(903);
        let be = par();
        let b = Matrix::random_normal(12, 9, &mut rng);
        let a_list: Vec<Matrix> = (0..7)
            .map(|_| Matrix::random_normal(10, 12, &mut rng))
            .collect();
        let mut batch: Vec<Matrix> = (0..7).map(|_| Matrix::zeros(10, 9)).collect();
        be.gemm_batch(1.0, &a_list, Trans::No, &b, Trans::No, 0.0, &mut batch);
        for (a, c) in a_list.iter().zip(&batch) {
            let want = SerialBackend.matmul(a, Trans::No, &b, Trans::No);
            close(c, &want, 1e-6);
        }
    }

    #[test]
    fn for_each_item_covers_every_index_once_serial_and_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for be in [&SerialBackend as &dyn ComputeBackend, &par()] {
            for n in [0usize, 1, 2, 7, 33] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                be.for_each_item(n, &|i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                    "{} n={n}",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn mttkrp_matches_serial_reference() {
        let mut rng = Xoshiro256::seed_from_u64(904);
        let (i, j, k, r) = (23, 7, 5, 4);
        let x1 = Matrix::random_normal(i, j * k, &mut rng);
        let b = Matrix::random_normal(j, r, &mut rng);
        let c = Matrix::random_normal(k, r, &mut rng);
        let fast = par().mttkrp(1, &x1, &c, &b);
        let slow = SerialBackend.mttkrp(1, &x1, &c, &b);
        close(&fast, &slow, 1e-6);
        assert_eq!((fast.rows(), fast.cols()), (i, r));
    }

    #[test]
    fn fused_mttkrp_matches_materialized_oracle_both_splits() {
        let mut rng = Xoshiro256::seed_from_u64(907);
        // (i, j, k) chosen so k ≥ 2·threads forces the panel split and
        // k < 2·threads with tall i forces the row split.
        for &(i, j, k, r) in &[(10usize, 6usize, 20usize, 3usize), (40, 9, 3, 5)] {
            let x1 = Matrix::random_normal(i, j * k, &mut rng);
            let b = Matrix::random_normal(j, r, &mut rng);
            let c = Matrix::random_normal(k, r, &mut rng);
            let oracle = mttkrp_materialized(&x1, &c, &b);
            close(&SerialBackend.mttkrp(1, &x1, &c, &b), &oracle, 1e-5);
            close(&par().mttkrp(1, &x1, &c, &b), &oracle, 1e-5);
        }
    }

    #[test]
    fn kr_gram_matches_materialized_gram() {
        let mut rng = Xoshiro256::seed_from_u64(908);
        let b = Matrix::random_normal(11, 4, &mut rng);
        let c = Matrix::random_normal(6, 4, &mut rng);
        let kr = khatri_rao(&c, &b);
        let want = SerialBackend.gram(&kr);
        close(&SerialBackend.kr_gram(&c, &b), &want, 1e-4);
        close(&par().kr_gram(&c, &b), &want, 1e-4);
    }

    #[test]
    fn gram_is_symmetric_and_matches() {
        let mut rng = Xoshiro256::seed_from_u64(905);
        let f = Matrix::random_normal(90, 6, &mut rng);
        let g_par = par().gram(&f);
        let g_ser = SerialBackend.gram(&f);
        close(&g_par, &g_ser, 1e-6);
        close(&g_par, &g_par.transpose(), 1e-5);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let be = par();
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        let c = be.matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!((c.rows(), c.cols()), (0, 4));
        // Single row/col strips narrower than the worker count.
        let mut rng = Xoshiro256::seed_from_u64(906);
        let a = Matrix::random_normal(1, 40, &mut rng);
        let b = Matrix::random_normal(40, 2, &mut rng);
        close(
            &be.matmul(&a, Trans::No, &b, Trans::No),
            &gemm_naive(&a, Trans::No, &b, Trans::No),
            1e-5,
        );
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn parallel_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = par().matmul(&a, Trans::No, &b, Trans::No);
    }

    #[test]
    fn partition_is_balanced_cover() {
        for n in [0usize, 1, 5, 16, 17] {
            for parts in [1usize, 2, 4, 9] {
                let ranges = ThreadPool::partition(n, parts);
                let total: usize = ranges.iter().map(|(a, b)| b - a).sum();
                assert_eq!(total, n);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                    assert!(w[0].1 - w[0].0 >= w[1].1 - w[1].0);
                }
            }
        }
    }
}
