//! Blocked GEMM — the CPU-baseline hot path.
//!
//! `gemm(alpha, A, opA, B, opB, beta, C)` computes
//! `C ← alpha · op(A) · op(B) + beta · C` with cache-blocked loops and a
//! column-major micro-kernel.  This is the routine the paper's "Baseline
//! (CPU)" variant spends its time in; the "GPU tensor core" variant replaces
//! it with the AOT Pallas artifact (see `runtime`).  §Perf iterates on the
//! block sizes below.

use super::matrix::Matrix;

/// Transpose flag for [`gemm`] operands.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    No,
    Yes,
}

// Cache-blocking parameters, tuned in EXPERIMENTS.md §Perf on the benchmark
// shapes (tall-skinny factors, fat unfoldings). MC×KC panel of A ~128 KB
// fits L2; KC×NC panel of B streams through L3.
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 512;

#[inline]
fn dims(m: &Matrix, t: Trans) -> (usize, usize) {
    match t {
        Trans::No => (m.rows(), m.cols()),
        Trans::Yes => (m.cols(), m.rows()),
    }
}

/// `C ← alpha · op(A)·op(B) + beta · C`.
///
/// Panics if shapes disagree.
pub fn gemm(alpha: f32, a: &Matrix, op_a: Trans, b: &Matrix, op_b: Trans, beta: f32, c: &mut Matrix) {
    let (m, k) = dims(a, op_a);
    let (k2, n) = dims(b, op_b);
    assert_eq!(k, k2, "gemm: inner dimension mismatch ({k} vs {k2})");
    assert_eq!(
        (c.rows(), c.cols()),
        (m, n),
        "gemm: output shape mismatch"
    );

    if beta != 1.0 {
        if beta == 0.0 {
            c.data_mut().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return;
    }

    // Pack op(A) panels into row-major and op(B) panels into column-major so
    // the micro-kernel streams both contiguously.  Buffers are sized to the
    // actual problem (§Perf): fixed MC·KC/KC·NC buffers cost ~640 KB of
    // zeroing per call, which dominates the thousands of small GEMMs in the
    // blocked TTM chain.
    let mut a_pack = vec![0.0f32; MC.min(m) * KC.min(k)];
    let mut b_pack = vec![0.0f32; KC.min(k) * NC.min(n)];

    let mut jc = 0;
    while jc < n {
        let nb = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kb = KC.min(k - pc);
            pack_b(b, op_b, pc, jc, kb, nb, &mut b_pack);
            let mut ic = 0;
            while ic < m {
                let mb = MC.min(m - ic);
                pack_a(a, op_a, ic, pc, mb, kb, &mut a_pack);
                micro_kernel(alpha, &a_pack, &b_pack, mb, nb, kb, c, ic, jc);
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// Packs `op(A)[ic..ic+mb, pc..pc+kb]` row-major into `out`.
fn pack_a(a: &Matrix, op: Trans, ic: usize, pc: usize, mb: usize, kb: usize, out: &mut [f32]) {
    match op {
        Trans::No => {
            for p in 0..kb {
                let col = a.col(pc + p);
                for i in 0..mb {
                    out[i * kb + p] = col[ic + i];
                }
            }
        }
        Trans::Yes => {
            // op(A)[i,p] = A[p,i]: columns of A become rows of op(A).
            for i in 0..mb {
                let col = a.col(ic + i);
                out[i * kb..i * kb + kb].copy_from_slice(&col[pc..pc + kb]);
            }
        }
    }
}

/// Packs `op(B)[pc..pc+kb, jc..jc+nb]` column-major into `out`.
fn pack_b(b: &Matrix, op: Trans, pc: usize, jc: usize, kb: usize, nb: usize, out: &mut [f32]) {
    match op {
        Trans::No => {
            for j in 0..nb {
                let col = b.col(jc + j);
                out[j * kb..j * kb + kb].copy_from_slice(&col[pc..pc + kb]);
            }
        }
        Trans::Yes => {
            for j in 0..nb {
                let base = j * kb;
                for p in 0..kb {
                    out[base + p] = b.get(jc + j, pc + p);
                }
            }
        }
    }
}

/// Inner kernel over packed panels: A row-major (mb×kb), B col-major (kb×nb).
///
/// Register blocking (§Perf): 4 output columns share each A-row pass, so
/// every `a` load feeds 4 FMAs — short-`k` GEMMs (the TTM chain's k=d
/// contractions) are load-bound in the 1-column variant.  Within the pass,
/// 4-wide `p` unrolling lets LLVM vectorize.
fn micro_kernel(
    alpha: f32,
    a_pack: &[f32],
    b_pack: &[f32],
    mb: usize,
    nb: usize,
    kb: usize,
    c: &mut Matrix,
    ic: usize,
    jc: usize,
) {
    let crows = c.rows();
    let cdata = c.data_mut();
    let mut j = 0;
    // 8-column blocks.
    while j + 8 <= nb {
        let bs: [&[f32]; 8] = [
            &b_pack[j * kb..(j + 1) * kb],
            &b_pack[(j + 1) * kb..(j + 2) * kb],
            &b_pack[(j + 2) * kb..(j + 3) * kb],
            &b_pack[(j + 3) * kb..(j + 4) * kb],
            &b_pack[(j + 4) * kb..(j + 5) * kb],
            &b_pack[(j + 5) * kb..(j + 6) * kb],
            &b_pack[(j + 6) * kb..(j + 7) * kb],
            &b_pack[(j + 7) * kb..(j + 8) * kb],
        ];
        let cb: [usize; 8] = core::array::from_fn(|q| ic + (jc + j + q) * crows);
        for i in 0..mb {
            let arow = &a_pack[i * kb..i * kb + kb];
            let mut d = [0.0f32; 8];
            for p in 0..kb {
                let a = arow[p];
                for q in 0..8 {
                    d[q] += a * bs[q][p];
                }
            }
            for q in 0..8 {
                cdata[cb[q] + i] += alpha * d[q];
            }
        }
        j += 8;
    }
    // 4-column blocks.
    while j + 4 <= nb {
        let b0 = &b_pack[j * kb..(j + 1) * kb];
        let b1 = &b_pack[(j + 1) * kb..(j + 2) * kb];
        let b2 = &b_pack[(j + 2) * kb..(j + 3) * kb];
        let b3 = &b_pack[(j + 3) * kb..(j + 4) * kb];
        let cb0 = ic + (jc + j) * crows;
        let cb1 = ic + (jc + j + 1) * crows;
        let cb2 = ic + (jc + j + 2) * crows;
        let cb3 = ic + (jc + j + 3) * crows;
        for i in 0..mb {
            let arow = &a_pack[i * kb..i * kb + kb];
            let (mut d0, mut d1, mut d2, mut d3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for p in 0..kb {
                let a = arow[p];
                d0 += a * b0[p];
                d1 += a * b1[p];
                d2 += a * b2[p];
                d3 += a * b3[p];
            }
            cdata[cb0 + i] += alpha * d0;
            cdata[cb1 + i] += alpha * d1;
            cdata[cb2 + i] += alpha * d2;
            cdata[cb3 + i] += alpha * d3;
        }
        j += 4;
    }
    // Remainder columns.
    while j < nb {
        let bcol = &b_pack[j * kb..j * kb + kb];
        let cbase = ic + (jc + j) * crows;
        for i in 0..mb {
            let arow = &a_pack[i * kb..i * kb + kb];
            let mut acc = [0.0f32; 4];
            let chunks = kb / 4;
            for q in 0..chunks {
                let p = q * 4;
                acc[0] += arow[p] * bcol[p];
                acc[1] += arow[p + 1] * bcol[p + 1];
                acc[2] += arow[p + 2] * bcol[p + 2];
                acc[3] += arow[p + 3] * bcol[p + 3];
            }
            let mut dot = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for p in chunks * 4..kb {
                dot += arow[p] * bcol[p];
            }
            cdata[cbase + i] += alpha * dot;
        }
        j += 1;
    }
}

/// Convenience: `op(A)·op(B)` into a fresh matrix.
pub fn matmul(a: &Matrix, op_a: Trans, b: &Matrix, op_b: Trans) -> Matrix {
    let (m, _) = dims(a, op_a);
    let (_, n) = dims(b, op_b);
    let mut c = Matrix::zeros(m, n);
    gemm(1.0, a, op_a, b, op_b, 0.0, &mut c);
    c
}

/// `y ← op(A)·x`.
pub fn matvec(a: &Matrix, op: Trans, x: &[f32]) -> Vec<f32> {
    let (m, k) = dims(a, op);
    assert_eq!(x.len(), k, "matvec: dimension mismatch");
    let mut y = vec![0.0f32; m];
    match op {
        Trans::No => {
            for (j, &xj) in x.iter().enumerate() {
                if xj == 0.0 {
                    continue;
                }
                let col = a.col(j);
                for i in 0..m {
                    y[i] += col[i] * xj;
                }
            }
        }
        Trans::Yes => {
            for (i, yi) in y.iter_mut().enumerate() {
                let col = a.col(i);
                let mut dot = 0.0;
                for (p, &xp) in x.iter().enumerate() {
                    dot += col[p] * xp;
                }
                *yi = dot;
            }
        }
    }
    y
}

/// Naive reference GEMM used to validate the blocked kernel in tests.
#[doc(hidden)]
pub fn gemm_naive(a: &Matrix, op_a: Trans, b: &Matrix, op_b: Trans) -> Matrix {
    let (m, k) = dims(a, op_a);
    let (_, n) = dims(b, op_b);
    let fetch_a = |i: usize, p: usize| match op_a {
        Trans::No => a.get(i, p),
        Trans::Yes => a.get(p, i),
    };
    let fetch_b = |p: usize, j: usize| match op_b {
        Trans::No => b.get(p, j),
        Trans::Yes => b.get(j, p),
    };
    Matrix::from_fn(m, n, |i, j| {
        let mut s = 0.0;
        for p in 0..k {
            s += fetch_a(i, p) * fetch_b(p, j);
        }
        s
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        let err = a.rel_error(b);
        assert!(err < tol, "rel error {err} > {tol}");
    }

    #[test]
    fn small_known_product() {
        let a = Matrix::from_rows(&[&[1., 2.], &[3., 4.]]);
        let b = Matrix::from_rows(&[&[5., 6.], &[7., 8.]]);
        let c = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!(c, Matrix::from_rows(&[&[19., 22.], &[43., 50.]]));
    }

    #[test]
    fn all_transpose_combinations_match_naive() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for &(m, k, n) in &[(3, 4, 5), (17, 9, 23), (64, 32, 48)] {
            for &op_a in &[Trans::No, Trans::Yes] {
                for &op_b in &[Trans::No, Trans::Yes] {
                    let (ar, ac) = if op_a == Trans::No { (m, k) } else { (k, m) };
                    let (br, bc) = if op_b == Trans::No { (k, n) } else { (n, k) };
                    let a = Matrix::random_normal(ar, ac, &mut rng);
                    let b = Matrix::random_normal(br, bc, &mut rng);
                    let fast = matmul(&a, op_a, &b, op_b);
                    let slow = gemm_naive(&a, op_a, &b, op_b);
                    assert_close(&fast, &slow, 1e-5);
                }
            }
        }
    }

    #[test]
    fn blocked_path_beyond_panel_sizes() {
        // Exercise multiple MC/KC/NC panels.
        let mut rng = Xoshiro256::seed_from_u64(7);
        let a = Matrix::random_normal(200, 300, &mut rng);
        let b = Matrix::random_normal(300, 600, &mut rng);
        let fast = matmul(&a, Trans::No, &b, Trans::No);
        let slow = gemm_naive(&a, Trans::No, &b, Trans::No);
        assert_close(&fast, &slow, 1e-4);
    }

    #[test]
    fn alpha_beta_semantics() {
        let a = Matrix::identity(3);
        let b = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let mut c = Matrix::from_fn(3, 3, |_, _| 1.0);
        gemm(2.0, &a, Trans::No, &b, Trans::No, 3.0, &mut c);
        // C = 2*B + 3*ones
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(c.get(i, j), 2.0 * b.get(i, j) + 3.0);
            }
        }
    }

    #[test]
    fn beta_zero_clears_nan() {
        let a = Matrix::identity(2);
        let mut c = Matrix::from_vec(2, 2, vec![f32::NAN; 4]);
        gemm(1.0, &a, Trans::No, &a, Trans::No, 0.0, &mut c);
        assert_eq!(c, Matrix::identity(2));
    }

    #[test]
    fn matvec_matches_gemm() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = Matrix::random_normal(13, 7, &mut rng);
        let x: Vec<f32> = rng.gaussian_vec_f32(7);
        let y = matvec(&a, Trans::No, &x);
        let xm = Matrix::from_vec(7, 1, x.clone());
        let ym = matmul(&a, Trans::No, &xm, Trans::No);
        for i in 0..13 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-5);
        }
        let yt = matvec(&a, Trans::Yes, &ym.into_vec());
        assert_eq!(yt.len(), 7);
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = matmul(&a, Trans::No, &b, Trans::No);
    }

    #[test]
    fn empty_matrices_ok() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        let c = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!((c.rows(), c.cols()), (0, 4));
    }
}
