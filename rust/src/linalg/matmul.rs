//! Blocked GEMM and the fused zero-materialization MTTKRP — the CPU hot path.
//!
//! Two kernels live here, sharing one packing/micro-kernel substrate:
//!
//! * [`gemm`]: `C ← alpha · op(A) · op(B) + beta · C` with BLIS-style cache
//!   blocking (`MC`/`KC`/`NC` macro panels) and a register-tiled `MR×NR`
//!   micro-kernel.
//! * [`mttkrp_fused`]: `X · (slow ⊙ fast)` where the Khatri-Rao operand is
//!   **never materialized** — its entries are synthesized column-by-column
//!   straight into the packed `KC×NC` B-panel ([`pack_b_khatri_rao`]), so
//!   the only place `(slow ⊙ fast)` values ever exist is a reusable
//!   `≤ KC·NC` scratch panel, regardless of how large `J·K` is.  This is
//!   the paper's scalability argument applied to the ALS hot spot: the
//!   `O(JK·R)` buffer that bounds tensor size on the materialized path
//!   simply does not exist.
//!
//! ## Dataflow
//!
//! ```text
//!   jc-loop (NC cols of C)                 B source: dense op(B) panel
//!     pc-loop (KC of the inner dim)  ──▶     OR virtual Khatri-Rao rows
//!       pack_b  → b_pack (KC×NC, NR-strips, zero-padded)
//!       ic-loop (MC rows of C)
//!         pack_a → a_pack (MC×KC, MR-strips, zero-padded)
//!         macro_kernel: MR×NR register tiles, FMA-friendly `i`-contiguous
//!                       inner loops that LLVM autovectorizes
//! ```
//!
//! ## Tiling constants
//!
//! `MC=128`, `KC=256`, `NC=512` keep the A panel (~128 KB) in L2 and stream
//! the B panel through L3 (tuned in EXPERIMENTS.md §Perf).  The register
//! tile is `MR×NR` with `NR = 4` output columns and `MR` rows gated on the
//! compile-time SIMD width: 8 (portable), 16 (`avx2`), 32 (`avx512f`).
//! Accumulators are `[[f32; MR]; NR]` arrays kept in vector registers; the
//! inner loop broadcasts one B value against `MR` contiguous packed A lanes.
//!
//! ## Scratch arena
//!
//! Pack buffers live in a thread-local [`PackArena`] and are reused across
//! calls: the thousands of small GEMMs in the blocked TTM chain no longer
//! allocate per call (the seed kernel paid two `vec![0.0; …]` per GEMM).
//! Pool workers get their own arena per scope; the caller thread's arena
//! persists for the life of the thread.

use super::matrix::Matrix;
use std::cell::RefCell;
use std::ops::Range;

/// Transpose flag for [`gemm`] operands.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    No,
    Yes,
}

// Cache-blocking parameters (macro tiles): MC×KC panel of A ~128 KB fits
// L2; KC×NC panel of B streams through L3.
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 512;

/// Register-tile rows: the packed-A strip width and the vector-lane axis of
/// the micro-kernel.  Gated on compile-time target features so
/// `-C target-cpu=native` (or `-C target-feature=+avx2`) widens the tile.
#[cfg(target_feature = "avx512f")]
pub const MR: usize = 32;
#[cfg(all(target_feature = "avx2", not(target_feature = "avx512f")))]
pub const MR: usize = 16;
#[cfg(not(any(target_feature = "avx2", target_feature = "avx512f")))]
pub const MR: usize = 8;

/// Register-tile columns: output columns sharing each packed-A pass, so
/// every A load feeds `NR` FMAs.  Column strips split along multiples of
/// `NR` reproduce the serial kernel bitwise (see `linalg::backend`).
pub const NR: usize = 4;

/// Reusable per-thread packing scratch: one A-panel and one B-panel buffer,
/// grown high-water-mark style and never shrunk.
#[derive(Default)]
struct PackArena {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl PackArena {
    /// Buffers sized for an `m×k` by `k×n` product under the current
    /// blocking (strip-padded to MR/NR multiples).
    fn reserve(&mut self, m: usize, n: usize, k: usize) -> (&mut [f32], &mut [f32]) {
        let a_need = MC.min(m).div_ceil(MR) * MR * KC.min(k);
        let b_need = NC.min(n).div_ceil(NR) * NR * KC.min(k);
        if self.a.len() < a_need {
            self.a.resize(a_need, 0.0);
        }
        if self.b.len() < b_need {
            self.b.resize(b_need, 0.0);
        }
        (&mut self.a[..a_need], &mut self.b[..b_need])
    }
}

thread_local! {
    static PACK_ARENA: RefCell<PackArena> = RefCell::new(PackArena::default());
}

#[inline]
fn dims(m: &Matrix, t: Trans) -> (usize, usize) {
    match t {
        Trans::No => (m.rows(), m.cols()),
        Trans::Yes => (m.cols(), m.rows()),
    }
}

/// `C ← alpha · op(A)·op(B) + beta · C`.
///
/// Panics if shapes disagree.  `beta = 0` clears `C` (including NaNs)
/// before accumulating.
pub fn gemm(
    alpha: f32,
    a: &Matrix,
    op_a: Trans,
    b: &Matrix,
    op_b: Trans,
    beta: f32,
    c: &mut Matrix,
) {
    let (m, k) = dims(a, op_a);
    let (k2, n) = dims(b, op_b);
    assert_eq!(k, k2, "gemm: inner dimension mismatch ({k} vs {k2})");
    assert_eq!(
        (c.rows(), c.cols()),
        (m, n),
        "gemm: output shape mismatch"
    );

    if beta != 1.0 {
        if beta == 0.0 {
            c.data_mut().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return;
    }

    PACK_ARENA.with(|cell| {
        let arena = &mut *cell.borrow_mut();
        let (a_pack, b_pack) = arena.reserve(m, n, k);
        let mut jc = 0;
        while jc < n {
            let nb = NC.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kb = KC.min(k - pc);
                pack_b(b, op_b, pc, jc, kb, nb, b_pack);
                let mut ic = 0;
                while ic < m {
                    let mb = MC.min(m - ic);
                    pack_a(a, op_a, ic, pc, mb, kb, a_pack);
                    macro_kernel(alpha, a_pack, b_pack, mb, nb, kb, c, ic, jc);
                    ic += MC;
                }
                pc += KC;
            }
            jc += NC;
        }
    });
}

/// Fused MTTKRP `X · (slow ⊙ fast)` into a fresh `I × R` matrix.
///
/// `X` is an `I × (J·K)` unfolding, `fast` is `J × R` (row index varies
/// fastest along X's columns), `slow` is `K × R`.  The Khatri-Rao operand
/// exists only as transient packed `KC×NC` panels — no `(J·K)×R`
/// intermediate is ever allocated.  The materialized reference
/// (`linalg::backend::mttkrp_materialized`) is kept as the test oracle.
pub fn mttkrp_fused(x: &Matrix, slow: &Matrix, fast: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), fast.cols());
    mttkrp_fused_acc(x, 0..x.rows(), 0..slow.rows(), slow, fast, &mut out);
    out
}

/// Accumulating fused-MTTKRP building block: adds the contribution of
/// unfolding rows `rows` and slow-factor panels `panels` into `out`
/// (shaped `rows.len() × R`), i.e.
/// `out += X[rows, panels·J..] · (slow[panels, :] ⊙ fast)`.
///
/// Summing over a partition of `panels` (or stacking over a partition of
/// `rows`) reproduces the full MTTKRP exactly — this is the splitting
/// invariant the parallel backend's panel/row decomposition relies on.
pub fn mttkrp_fused_acc(
    x: &Matrix,
    rows: Range<usize>,
    panels: Range<usize>,
    slow: &Matrix,
    fast: &Matrix,
    out: &mut Matrix,
) {
    let jdim = fast.rows();
    let kdim = slow.rows();
    let r = fast.cols();
    assert_eq!(slow.cols(), r, "mttkrp_fused: rank mismatch");
    assert_eq!(
        x.cols(),
        jdim * kdim,
        "mttkrp_fused: unfolding has {} columns but slow×fast = {}×{}",
        x.cols(),
        kdim,
        jdim
    );
    assert!(rows.start <= rows.end && rows.end <= x.rows(), "mttkrp_fused: row range");
    assert!(
        panels.start <= panels.end && panels.end <= kdim,
        "mttkrp_fused: panel range"
    );
    let m = rows.end - rows.start;
    assert_eq!(
        (out.rows(), out.cols()),
        (m, r),
        "mttkrp_fused: accumulator shape mismatch"
    );
    // Virtual Khatri-Rao row range covered by the requested panels.
    let p0 = panels.start * jdim;
    let p1 = panels.end * jdim;
    if m == 0 || r == 0 || p0 == p1 {
        return;
    }

    PACK_ARENA.with(|cell| {
        let arena = &mut *cell.borrow_mut();
        let (a_pack, b_pack) = arena.reserve(m, r, p1 - p0);
        let mut jc = 0;
        while jc < r {
            let nb = NC.min(r - jc);
            let mut pc = p0;
            while pc < p1 {
                let kb = KC.min(p1 - pc);
                pack_b_khatri_rao(slow, fast, pc, jc, kb, nb, b_pack);
                let mut ic = 0;
                while ic < m {
                    let mb = MC.min(m - ic);
                    pack_a(x, Trans::No, rows.start + ic, pc, mb, kb, a_pack);
                    macro_kernel(1.0, a_pack, b_pack, mb, nb, kb, out, ic, jc);
                    ic += MC;
                }
                pc += KC;
            }
            jc += NC;
        }
    });
}

/// Packs `op(A)[ic..ic+mb, pc..pc+kb]` into MR-row strips: strip `s` holds
/// rows `s·MR..s·MR+MR` with element `(i, p)` at `s·kb·MR + p·MR + i`, rows
/// beyond `mb` zero-padded so the micro-kernel never branches on ragged
/// edges.
fn pack_a(a: &Matrix, op: Trans, ic: usize, pc: usize, mb: usize, kb: usize, out: &mut [f32]) {
    let strips = mb.div_ceil(MR);
    match op {
        Trans::No => {
            for s in 0..strips {
                let base = s * kb * MR;
                let i0 = ic + s * MR;
                let rs = MR.min(mb - s * MR);
                for p in 0..kb {
                    let col = &a.col(pc + p)[i0..i0 + rs];
                    let dst = &mut out[base + p * MR..base + (p + 1) * MR];
                    dst[..rs].copy_from_slice(col);
                    dst[rs..].fill(0.0);
                }
            }
        }
        Trans::Yes => {
            // op(A)[i, p] = A[pc+p, ic+i]: column ic+i of A is row i of
            // op(A), contiguous over p.
            for s in 0..strips {
                let base = s * kb * MR;
                let rs = MR.min(mb - s * MR);
                for ii in 0..MR {
                    if ii < rs {
                        let col = &a.col(ic + s * MR + ii)[pc..pc + kb];
                        for (p, &v) in col.iter().enumerate() {
                            out[base + p * MR + ii] = v;
                        }
                    } else {
                        for p in 0..kb {
                            out[base + p * MR + ii] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// Packs `op(B)[pc..pc+kb, jc..jc+nb]` into NR-column strips: strip `s`
/// holds columns `s·NR..s·NR+NR` with element `(p, q)` at
/// `s·kb·NR + p·NR + q`, columns beyond `nb` zero-padded.
fn pack_b(b: &Matrix, op: Trans, pc: usize, jc: usize, kb: usize, nb: usize, out: &mut [f32]) {
    let strips = nb.div_ceil(NR);
    for s in 0..strips {
        let base = s * kb * NR;
        for q in 0..NR {
            let jq = s * NR + q;
            if jq >= nb {
                for p in 0..kb {
                    out[base + p * NR + q] = 0.0;
                }
                continue;
            }
            match op {
                Trans::No => {
                    let col = &b.col(jc + jq)[pc..pc + kb];
                    for (p, &v) in col.iter().enumerate() {
                        out[base + p * NR + q] = v;
                    }
                }
                Trans::Yes => {
                    for p in 0..kb {
                        out[base + p * NR + q] = b.get(jc + jq, pc + p);
                    }
                }
            }
        }
    }
}

/// Packs rows `pc..pc+kb`, columns `jc..jc+nb` of the **virtual** Khatri-Rao
/// operand `slow ⊙ fast` — `(slow ⊙ fast)[j + k·J, r] = slow[k,r]·fast[j,r]`
/// — into the same NR-strip layout as [`pack_b`].  Entries are generated on
/// the fly from the factor columns with running `(j, k)` counters (no
/// per-row div/mod); this packed panel is the only place Khatri-Rao values
/// ever exist.
fn pack_b_khatri_rao(
    slow: &Matrix,
    fast: &Matrix,
    pc: usize,
    jc: usize,
    kb: usize,
    nb: usize,
    out: &mut [f32],
) {
    let jdim = fast.rows();
    let strips = nb.div_ceil(NR);
    for s in 0..strips {
        let base = s * kb * NR;
        for q in 0..NR {
            let jq = s * NR + q;
            if jq >= nb {
                for p in 0..kb {
                    out[base + p * NR + q] = 0.0;
                }
                continue;
            }
            let fcol = fast.col(jc + jq);
            let scol = slow.col(jc + jq);
            let (mut k, mut j) = (pc / jdim, pc % jdim);
            let mut sv = scol[k];
            for p in 0..kb {
                out[base + p * NR + q] = sv * fcol[j];
                j += 1;
                if j == jdim {
                    j = 0;
                    k += 1;
                    if k < scol.len() {
                        sv = scol[k];
                    }
                }
            }
        }
    }
}

/// Drives the register-tiled micro-kernel over every `MR×NR` tile of one
/// packed `mb×kb` × `kb×nb` macro block, accumulating into `C` at offset
/// `(ic, jc)`.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    alpha: f32,
    a_pack: &[f32],
    b_pack: &[f32],
    mb: usize,
    nb: usize,
    kb: usize,
    c: &mut Matrix,
    ic: usize,
    jc: usize,
) {
    let crows = c.rows();
    let cdata = c.data_mut();
    let m_strips = mb.div_ceil(MR);
    let n_strips = nb.div_ceil(NR);
    for js in 0..n_strips {
        let b_strip = &b_pack[js * kb * NR..(js + 1) * kb * NR];
        let nr = NR.min(nb - js * NR);
        for is in 0..m_strips {
            let a_strip = &a_pack[is * kb * MR..(is + 1) * kb * MR];
            let mr = MR.min(mb - is * MR);
            micro_kernel(
                alpha,
                a_strip,
                b_strip,
                kb,
                mr,
                nr,
                cdata,
                crows,
                ic + is * MR,
                jc + js * NR,
            );
        }
    }
}

/// One `MR×NR` register tile: `MR·NR` accumulators held in vector
/// registers; each step of the `p` loop broadcasts one packed-B value
/// against `MR` contiguous packed-A lanes (an FMA per lane — LLVM
/// autovectorizes the `i` loop since both sides are contiguous and
/// reduction-free).  The zero-padded packing means full-width arithmetic
/// always; only the epilogue write-back is clipped to the valid `mr×nr`
/// corner.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    alpha: f32,
    a_strip: &[f32],
    b_strip: &[f32],
    kb: usize,
    mr: usize,
    nr: usize,
    cdata: &mut [f32],
    crows: usize,
    ci: usize,
    cj: usize,
) {
    let mut acc = [[0.0f32; MR]; NR];
    for p in 0..kb {
        let av = &a_strip[p * MR..(p + 1) * MR];
        let bv = &b_strip[p * NR..(p + 1) * NR];
        for q in 0..NR {
            let b = bv[q];
            for i in 0..MR {
                acc[q][i] += av[i] * b;
            }
        }
    }
    for (q, acc_col) in acc.iter().enumerate().take(nr) {
        let base = ci + (cj + q) * crows;
        let col = &mut cdata[base..base + mr];
        if alpha == 1.0 {
            for (dst, &v) in col.iter_mut().zip(acc_col.iter()) {
                *dst += v;
            }
        } else {
            for (dst, &v) in col.iter_mut().zip(acc_col.iter()) {
                *dst += alpha * v;
            }
        }
    }
}

/// Convenience: `op(A)·op(B)` into a fresh matrix.
pub fn matmul(a: &Matrix, op_a: Trans, b: &Matrix, op_b: Trans) -> Matrix {
    let (m, _) = dims(a, op_a);
    let (_, n) = dims(b, op_b);
    let mut c = Matrix::zeros(m, n);
    gemm(1.0, a, op_a, b, op_b, 0.0, &mut c);
    c
}

/// `y ← op(A)·x`.
pub fn matvec(a: &Matrix, op: Trans, x: &[f32]) -> Vec<f32> {
    let (m, k) = dims(a, op);
    assert_eq!(x.len(), k, "matvec: dimension mismatch");
    let mut y = vec![0.0f32; m];
    match op {
        Trans::No => {
            for (j, &xj) in x.iter().enumerate() {
                if xj == 0.0 {
                    continue;
                }
                let col = a.col(j);
                for i in 0..m {
                    y[i] += col[i] * xj;
                }
            }
        }
        Trans::Yes => {
            for (i, yi) in y.iter_mut().enumerate() {
                let col = a.col(i);
                let mut dot = 0.0;
                for (p, &xp) in x.iter().enumerate() {
                    dot += col[p] * xp;
                }
                *yi = dot;
            }
        }
    }
    y
}

/// Naive reference GEMM used to validate the blocked kernel in tests.
#[doc(hidden)]
pub fn gemm_naive(a: &Matrix, op_a: Trans, b: &Matrix, op_b: Trans) -> Matrix {
    let (m, k) = dims(a, op_a);
    let (_, n) = dims(b, op_b);
    let fetch_a = |i: usize, p: usize| match op_a {
        Trans::No => a.get(i, p),
        Trans::Yes => a.get(p, i),
    };
    let fetch_b = |p: usize, j: usize| match op_b {
        Trans::No => b.get(p, j),
        Trans::Yes => b.get(j, p),
    };
    Matrix::from_fn(m, n, |i, j| {
        let mut s = 0.0;
        for p in 0..k {
            s += fetch_a(i, p) * fetch_b(p, j);
        }
        s
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::products::khatri_rao;
    use crate::util::rng::Xoshiro256;

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        let err = a.rel_error(b);
        assert!(err < tol, "rel error {err} > {tol}");
    }

    #[test]
    fn small_known_product() {
        let a = Matrix::from_rows(&[&[1., 2.], &[3., 4.]]);
        let b = Matrix::from_rows(&[&[5., 6.], &[7., 8.]]);
        let c = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!(c, Matrix::from_rows(&[&[19., 22.], &[43., 50.]]));
    }

    #[test]
    fn all_transpose_combinations_match_naive() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for &(m, k, n) in &[(3, 4, 5), (17, 9, 23), (64, 32, 48)] {
            for &op_a in &[Trans::No, Trans::Yes] {
                for &op_b in &[Trans::No, Trans::Yes] {
                    let (ar, ac) = if op_a == Trans::No { (m, k) } else { (k, m) };
                    let (br, bc) = if op_b == Trans::No { (k, n) } else { (n, k) };
                    let a = Matrix::random_normal(ar, ac, &mut rng);
                    let b = Matrix::random_normal(br, bc, &mut rng);
                    let fast = matmul(&a, op_a, &b, op_b);
                    let slow = gemm_naive(&a, op_a, &b, op_b);
                    assert_close(&fast, &slow, 1e-5);
                }
            }
        }
    }

    #[test]
    fn ragged_register_tile_edges_match_naive() {
        // Shapes straddling every MR/NR boundary (including MR±1 rows and
        // NR±1 columns) so edge-tile zero-padding and clipped write-back
        // are both exercised.
        let mut rng = Xoshiro256::seed_from_u64(43);
        for &m in &[1usize, MR - 1, MR, MR + 1, 2 * MR + 3] {
            for &n in &[1usize, NR - 1, NR, NR + 1, 3 * NR + 1] {
                for &k in &[1usize, 5, KC + 7] {
                    let a = Matrix::random_normal(m, k, &mut rng);
                    let b = Matrix::random_normal(k, n, &mut rng);
                    let fast = matmul(&a, Trans::No, &b, Trans::No);
                    let slow = gemm_naive(&a, Trans::No, &b, Trans::No);
                    assert_close(&fast, &slow, 1e-4);
                }
            }
        }
    }

    #[test]
    fn blocked_path_beyond_panel_sizes() {
        // Exercise multiple MC/KC/NC panels.
        let mut rng = Xoshiro256::seed_from_u64(7);
        let a = Matrix::random_normal(200, 300, &mut rng);
        let b = Matrix::random_normal(300, 600, &mut rng);
        let fast = matmul(&a, Trans::No, &b, Trans::No);
        let slow = gemm_naive(&a, Trans::No, &b, Trans::No);
        assert_close(&fast, &slow, 1e-4);
    }

    #[test]
    fn alpha_beta_semantics() {
        let a = Matrix::identity(3);
        let b = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let mut c = Matrix::from_fn(3, 3, |_, _| 1.0);
        gemm(2.0, &a, Trans::No, &b, Trans::No, 3.0, &mut c);
        // C = 2*B + 3*ones
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(c.get(i, j), 2.0 * b.get(i, j) + 3.0);
            }
        }
    }

    #[test]
    fn beta_zero_clears_nan() {
        let a = Matrix::identity(2);
        let mut c = Matrix::from_vec(2, 2, vec![f32::NAN; 4]);
        gemm(1.0, &a, Trans::No, &a, Trans::No, 0.0, &mut c);
        assert_eq!(c, Matrix::identity(2));
    }

    #[test]
    fn matvec_matches_gemm() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = Matrix::random_normal(13, 7, &mut rng);
        let x: Vec<f32> = rng.gaussian_vec_f32(7);
        let y = matvec(&a, Trans::No, &x);
        let xm = Matrix::from_vec(7, 1, x.clone());
        let ym = matmul(&a, Trans::No, &xm, Trans::No);
        for i in 0..13 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-5);
        }
        let yt = matvec(&a, Trans::Yes, &ym.into_vec());
        assert_eq!(yt.len(), 7);
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = matmul(&a, Trans::No, &b, Trans::No);
    }

    #[test]
    fn empty_matrices_ok() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        let c = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!((c.rows(), c.cols()), (0, 4));
    }

    #[test]
    fn fused_mttkrp_matches_materialized() {
        let mut rng = Xoshiro256::seed_from_u64(500);
        for &(i, j, k, r) in &[
            (9usize, 8usize, 7usize, 3usize),
            (33, 5, 41, 6),
            (1, 17, 1, 2),
            (130, 70, 3, 16),
        ] {
            let x = Matrix::random_normal(i, j * k, &mut rng);
            let fast = Matrix::random_normal(j, r, &mut rng);
            let slow = Matrix::random_normal(k, r, &mut rng);
            let fused = mttkrp_fused(&x, &slow, &fast);
            let kr = khatri_rao(&slow, &fast);
            let reference = matmul(&x, Trans::No, &kr, Trans::No);
            assert_close(&fused, &reference, 1e-4);
        }
    }

    #[test]
    fn fused_mttkrp_spans_multiple_kc_panels() {
        // J·K = 24·32 = 768 > KC: the virtual Khatri-Rao operand is packed
        // across three KC panels and accumulated.
        let mut rng = Xoshiro256::seed_from_u64(501);
        let (i, j, k, r) = (20usize, 24usize, 32usize, 5usize);
        let x = Matrix::random_normal(i, j * k, &mut rng);
        let fast = Matrix::random_normal(j, r, &mut rng);
        let slow = Matrix::random_normal(k, r, &mut rng);
        let fused = mttkrp_fused(&x, &slow, &fast);
        let reference = matmul(&x, Trans::No, &khatri_rao(&slow, &fast), Trans::No);
        assert_close(&fused, &reference, 1e-4);
    }

    #[test]
    fn fused_acc_panel_partition_sums_to_full() {
        // The parallel backend's splitting invariant: accumulating disjoint
        // panel ranges into one output equals the full fused MTTKRP, and a
        // row-range strip equals the matching rows of the full result.
        let mut rng = Xoshiro256::seed_from_u64(502);
        let (i, j, k, r) = (15usize, 7usize, 11usize, 4usize);
        let x = Matrix::random_normal(i, j * k, &mut rng);
        let fast = Matrix::random_normal(j, r, &mut rng);
        let slow = Matrix::random_normal(k, r, &mut rng);
        let full = mttkrp_fused(&x, &slow, &fast);

        let mut acc = Matrix::zeros(i, r);
        for (k0, k1) in [(0usize, 4usize), (4, 5), (5, 11)] {
            mttkrp_fused_acc(&x, 0..i, k0..k1, &slow, &fast, &mut acc);
        }
        assert_close(&acc, &full, 1e-5);

        let mut strip = Matrix::zeros(5, r);
        mttkrp_fused_acc(&x, 3..8, 0..k, &slow, &fast, &mut strip);
        assert_close(&strip, &full.slice_rows(3, 8), 1e-5);
    }

    #[test]
    fn fused_mttkrp_empty_ranges_are_noops() {
        let mut rng = Xoshiro256::seed_from_u64(503);
        let x = Matrix::random_normal(6, 12, &mut rng);
        let fast = Matrix::random_normal(4, 2, &mut rng);
        let slow = Matrix::random_normal(3, 2, &mut rng);
        let mut out = Matrix::zeros(6, 2);
        mttkrp_fused_acc(&x, 0..6, 2..2, &slow, &fast, &mut out);
        assert_eq!(out, Matrix::zeros(6, 2));
        let mut empty = Matrix::zeros(0, 2);
        mttkrp_fused_acc(&x, 4..4, 0..3, &slow, &fast, &mut empty);
        assert_eq!((empty.rows(), empty.cols()), (0, 2));
    }

    #[test]
    #[should_panic(expected = "unfolding has")]
    fn fused_mttkrp_shape_mismatch_panics() {
        let x = Matrix::zeros(3, 10);
        let fast = Matrix::zeros(4, 2);
        let slow = Matrix::zeros(3, 2);
        let _ = mttkrp_fused(&x, &slow, &fast);
    }
}
