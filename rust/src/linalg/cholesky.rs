//! Cholesky factorization and SPD solves.
//!
//! The ALS normal equations (Alg. 1 line 3) solve against Gram-matrix
//! products `(CᵀC * BᵀB)` which are symmetric positive (semi-)definite of
//! size `R×R` — tiny — so an unblocked Cholesky with a diagonal-jitter
//! retry is the right tool.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
///
/// Fails if `A` is not positive definite (after one jitter retry is the
/// caller's job — see [`cholesky_solve`]).
pub fn cholesky_factor(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    if a.cols() != n {
        bail!("cholesky: matrix must be square, got {}x{}", n, a.cols());
    }
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        // diagonal
        let mut d = a.get(j, j) as f64;
        for k in 0..j {
            let ljk = l.get(j, k) as f64;
            d -= ljk * ljk;
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("cholesky: not positive definite at pivot {j} (d={d})");
        }
        let dj = d.sqrt();
        l.set(j, j, dj as f32);
        // below-diagonal column j
        for i in (j + 1)..n {
            let mut s = a.get(i, j) as f64;
            for k in 0..j {
                s -= l.get(i, k) as f64 * l.get(j, k) as f64;
            }
            l.set(i, j, (s / dj) as f32);
        }
    }
    Ok(l)
}

/// Solves `A·X = B` for SPD `A` via Cholesky with forward/back substitution.
/// Retries once with diagonal jitter `1e-6·trace/n` if the factorization
/// fails (rank-deficient Gram matrices appear when ALS collapses columns).
pub fn cholesky_solve(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    let l = match cholesky_factor(a) {
        Ok(l) => l,
        Err(_) => {
            let n = a.rows();
            let tr: f64 = (0..n).map(|i| a.get(i, i) as f64).sum();
            let jitter = (1e-6 * tr / n as f64).max(1e-10) as f32;
            let mut aj = a.clone();
            for i in 0..n {
                aj.add_assign_at(i, i, jitter);
            }
            cholesky_factor(&aj)?
        }
    };
    Ok(solve_with_factor(&l, b))
}

/// Given the lower factor `L`, solves `L·Lᵀ·X = B`.
pub fn solve_with_factor(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let mut x = b.clone();
    for col in 0..x.cols() {
        // forward: L y = b
        for i in 0..n {
            let mut s = x.get(i, col) as f64;
            for k in 0..i {
                s -= l.get(i, k) as f64 * x.get(k, col) as f64;
            }
            x.set(i, col, (s / l.get(i, i) as f64) as f32);
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = x.get(i, col) as f64;
            for k in (i + 1)..n {
                s -= l.get(k, i) as f64 * x.get(k, col) as f64;
            }
            x.set(i, col, (s / l.get(i, i) as f64) as f32);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, Trans};
    use crate::util::rng::Xoshiro256;

    fn spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
        // G = MᵀM + n·I is SPD.
        let m = Matrix::random_normal(n + 2, n, rng);
        let mut g = matmul(&m, Trans::Yes, &m, Trans::No);
        for i in 0..n {
            g.add_assign_at(i, i, n as f32);
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = spd(8, &mut rng);
        let l = cholesky_factor(&a).unwrap();
        let llt = matmul(&l, Trans::No, &l, Trans::Yes);
        assert!(llt.rel_error(&a) < 1e-5);
        // strictly lower-triangular above diagonal is zero
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_eq!(l.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn solve_recovers_known_x() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let a = spd(10, &mut rng);
        let x_true = Matrix::random_normal(10, 3, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let x = cholesky_solve(&a, &b).unwrap();
        assert!(x.rel_error(&x_true) < 1e-4);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky_factor(&a).is_err());
    }

    #[test]
    fn jitter_handles_singular() {
        // Rank-1 Gram matrix — singular, but solve should still return
        // something finite via the jitter path.
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let b = Matrix::from_rows(&[&[1.0], &[1.0]]);
        let x = cholesky_solve(&a, &b).unwrap();
        assert!(x.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn identity_solve_is_rhs() {
        let i = Matrix::identity(5);
        let b = Matrix::from_fn(5, 2, |r, c| (r + c) as f32);
        let x = cholesky_solve(&i, &b).unwrap();
        assert!(x.rel_error(&b) < 1e-6);
    }

    #[test]
    fn non_square_rejected() {
        let a = Matrix::zeros(3, 4);
        assert!(cholesky_factor(&a).is_err());
    }
}
