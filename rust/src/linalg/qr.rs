//! Householder QR and QR-based least squares.
//!
//! Used where the normal equations are too ill-conditioned: the stacked
//! recovery solve of Eq. (4) when `P·L` barely exceeds `I`, and the HOSVD
//! init's orthonormalization.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Compact Householder QR of `A (m×n, m ≥ n)`: returns `(qr, tau)` where the
/// upper triangle of `qr` is `R` and the columns below the diagonal hold the
/// Householder vectors (LAPACK `geqrf` layout).
pub fn qr_decompose(a: &Matrix) -> (Matrix, Vec<f32>) {
    let m = a.rows();
    let n = a.cols();
    let mut qr = a.clone();
    let mut tau = vec![0.0f32; n.min(m)];

    for k in 0..n.min(m) {
        // Householder vector for column k below row k.
        let mut norm2 = 0.0f64;
        for i in k..m {
            let v = qr.get(i, k) as f64;
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            tau[k] = 0.0;
            continue;
        }
        let akk = qr.get(k, k) as f64;
        let alpha = if akk >= 0.0 { -norm } else { norm };
        // v = x − α·e1, normalized so v[k] = 1 (store v_i/v0 below the
        // diagonal, LAPACK-style); H = I − τ·v·vᵀ with τ = 2·v0²/vᵀv.
        let v0 = akk - alpha;
        if v0 == 0.0 {
            tau[k] = 0.0;
            continue;
        }
        let mut vtv = v0 * v0;
        for i in (k + 1)..m {
            let v = qr.get(i, k) as f64;
            vtv += v * v;
        }
        tau[k] = (2.0 * v0 * v0 / vtv) as f32;
        qr.set(k, k, alpha as f32); // R diagonal
        for i in (k + 1)..m {
            let v = qr.get(i, k) as f64 / v0;
            qr.set(i, k, v as f32);
        }
        // Apply H = I - tau v vᵀ to remaining columns.
        for j in (k + 1)..n {
            // w = vᵀ A[:, j]
            let mut w = qr.get(k, j) as f64; // v_k = 1
            for i in (k + 1)..m {
                w += qr.get(i, k) as f64 * qr.get(i, j) as f64;
            }
            w *= tau[k] as f64;
            qr.set(k, j, (qr.get(k, j) as f64 - w) as f32);
            for i in (k + 1)..m {
                let newv = qr.get(i, j) as f64 - w * qr.get(i, k) as f64;
                qr.set(i, j, newv as f32);
            }
        }
    }
    (qr, tau)
}

/// Applies `Qᵀ` (from [`qr_decompose`]) to `b` in place.
fn apply_qt(qr: &Matrix, tau: &[f32], b: &mut Matrix) {
    let m = qr.rows();
    let n = qr.cols().min(m);
    for k in 0..n {
        if tau[k] == 0.0 {
            continue;
        }
        for col in 0..b.cols() {
            let mut w = b.get(k, col) as f64;
            for i in (k + 1)..m {
                w += qr.get(i, k) as f64 * b.get(i, col) as f64;
            }
            w *= tau[k] as f64;
            b.set(k, col, (b.get(k, col) as f64 - w) as f32);
            for i in (k + 1)..m {
                let newv = b.get(i, col) as f64 - w * qr.get(i, k) as f64;
                b.set(i, col, newv as f32);
            }
        }
    }
}

/// Least-squares solve `min ‖A·X − B‖` via QR for `A (m×n, m ≥ n)` full rank.
pub fn qr_solve(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    let m = a.rows();
    let n = a.cols();
    if m < n {
        bail!("qr_solve: underdetermined system ({m} rows < {n} cols)");
    }
    if b.rows() != m {
        bail!("qr_solve: rhs rows {} != {m}", b.rows());
    }
    let (qr, tau) = qr_decompose(a);
    let mut qtb = b.clone();
    apply_qt(&qr, &tau, &mut qtb);
    // Back substitution on R (n×n upper-triangular). Rank deficiency is
    // judged relative to the largest diagonal (f32 inputs: absolute 1e-12
    // would never trigger).
    let rmax = (0..n).map(|i| qr.get(i, i).abs()).fold(0.0f32, f32::max) as f64;
    let mut x = Matrix::zeros(n, b.cols());
    for col in 0..b.cols() {
        for i in (0..n).rev() {
            let mut s = qtb.get(i, col) as f64;
            for k in (i + 1)..n {
                s -= qr.get(i, k) as f64 * x.get(k, col) as f64;
            }
            let rii = qr.get(i, i) as f64;
            if rii.abs() < 1e-6 * rmax.max(1e-30) {
                bail!("qr_solve: rank-deficient (R[{i},{i}] ≈ 0)");
            }
            x.set(i, col, (s / rii) as f32);
        }
    }
    Ok(x)
}

/// Extracts an explicit orthonormal `Q (m×n)` — used by the HOSVD init.
pub fn qr_q(a: &Matrix) -> Matrix {
    let m = a.rows();
    let n = a.cols().min(m);
    let (qr, tau) = qr_decompose(a);
    // Q = H_0 H_1 … H_{n-1} applied to the first n columns of I.
    let mut q = Matrix::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        if tau[k] == 0.0 {
            continue;
        }
        for col in 0..n {
            let mut w = q.get(k, col) as f64;
            for i in (k + 1)..m {
                w += qr.get(i, k) as f64 * q.get(i, col) as f64;
            }
            w *= tau[k] as f64;
            q.set(k, col, (q.get(k, col) as f64 - w) as f32);
            for i in (k + 1)..m {
                let newv = q.get(i, col) as f64 - w * qr.get(i, k) as f64;
                q.set(i, col, newv as f32);
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, Trans};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn qr_solve_exact_system() {
        let a = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 3.0], &[0.0, 0.0]]);
        let b = Matrix::from_rows(&[&[4.0], &[9.0], &[0.0]]);
        let x = qr_solve(&a, &b).unwrap();
        assert!((x.get(0, 0) - 2.0).abs() < 1e-5);
        assert!((x.get(1, 0) - 3.0).abs() < 1e-5);
    }

    #[test]
    fn qr_solve_recovers_planted_solution() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let a = Matrix::random_normal(40, 12, &mut rng);
        let x_true = Matrix::random_normal(12, 4, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let x = qr_solve(&a, &b).unwrap();
        assert!(x.rel_error(&x_true) < 1e-4, "err={}", x.rel_error(&x_true));
    }

    #[test]
    fn qr_solve_overdetermined_minimizes_residual() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let a = Matrix::random_normal(30, 5, &mut rng);
        let b = Matrix::random_normal(30, 1, &mut rng);
        let x = qr_solve(&a, &b).unwrap();
        // Residual must be orthogonal to the column space: Aᵀ(Ax − b) ≈ 0.
        let ax = matmul(&a, Trans::No, &x, Trans::No);
        let r = ax.sub(&b);
        let g = matmul(&a, Trans::Yes, &r, Trans::No);
        assert!(g.max_abs() < 1e-3, "gradient norm {}", g.max_abs());
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let a = Matrix::random_normal(20, 8, &mut rng);
        let q = qr_q(&a);
        let qtq = matmul(&q, Trans::Yes, &q, Trans::No);
        assert!(qtq.rel_error(&Matrix::identity(8)) < 1e-4);
    }

    #[test]
    fn q_spans_column_space() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let a = Matrix::random_normal(15, 6, &mut rng);
        let q = qr_q(&a);
        // A = Q Qᵀ A (projection identity when Q spans col(A)).
        let qta = matmul(&q, Trans::Yes, &a, Trans::No);
        let rec = matmul(&q, Trans::No, &qta, Trans::No);
        assert!(rec.rel_error(&a) < 1e-4);
    }

    #[test]
    fn underdetermined_rejected() {
        let a = Matrix::zeros(3, 5);
        let b = Matrix::zeros(3, 1);
        assert!(qr_solve(&a, &b).is_err());
    }

    #[test]
    fn rank_deficient_detected() {
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0], &[1.0, 1.0]]);
        let b = Matrix::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        assert!(qr_solve(&a, &b).is_err());
    }
}
