//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Needed by the HOSVD initialization (leading eigenvectors of the Gram
//! matrices of each unfolding) and by the congruence diagnostics.  Gram
//! matrices here are at most a few hundred square, where Jacobi is simple
//! and robust.

use super::matrix::Matrix;

/// Eigendecomposition of a symmetric matrix: `A = V·diag(w)·Vᵀ`.
/// Returns `(w, V)` with eigenvalues sorted **descending** and eigenvectors
/// in the corresponding columns of `V`.
pub fn sym_eig(a: &Matrix) -> (Vec<f32>, Matrix) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "sym_eig: square matrix required");
    // Work in f64 for stability.
    let mut m: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let idx = |i: usize, j: usize| i + j * n;
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[idx(i, i)] = 1.0;
    }

    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for j in 0..n {
            for i in 0..j {
                off += m[idx(i, j)] * m[idx(i, j)];
            }
        }
        if off.sqrt() < 1e-11 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of M.
                for k in 0..n {
                    let mkp = m[idx(k, p)];
                    let mkq = m[idx(k, q)];
                    m[idx(k, p)] = c * mkp - s * mkq;
                    m[idx(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[idx(p, k)];
                    let mqk = m[idx(q, k)];
                    m[idx(p, k)] = c * mpk - s * mqk;
                    m[idx(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract, sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[idx(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let w: Vec<f32> = pairs.iter().map(|&(val, _)| val as f32).collect();
    let mut vm = Matrix::zeros(n, n);
    for (out_col, &(_, src_col)) in pairs.iter().enumerate() {
        for i in 0..n {
            vm.set(i, out_col, v[idx(i, src_col)] as f32);
        }
    }
    (w, vm)
}

/// Leading `k` eigenvectors of a symmetric matrix (descending eigenvalues).
pub fn leading_eigvecs(a: &Matrix, k: usize) -> Matrix {
    let (_, v) = sym_eig(a);
    v.slice_cols(0, k.min(v.cols()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, Trans};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn diagonal_matrix_eigs() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 1.0]]);
        let (w, v) = sym_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-5);
        assert!((w[1] - 1.0).abs() < 1e-5);
        assert!(v.get(0, 0).abs() > 0.99);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (w, _) = sym_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-5);
        assert!((w[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn reconstructs_random_symmetric() {
        let mut rng = Xoshiro256::seed_from_u64(10);
        let b = Matrix::random_normal(12, 12, &mut rng);
        let a = matmul(&b, Trans::Yes, &b, Trans::No); // SPD
        let (w, v) = sym_eig(&a);
        // A ≈ V diag(w) Vᵀ
        let vd = v.scale_cols(&w);
        let rec = matmul(&vd, Trans::No, &v, Trans::Yes);
        assert!(rec.rel_error(&a) < 1e-4, "err={}", rec.rel_error(&a));
        // eigenvalues descending and nonnegative for SPD
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-4);
            assert!(w[i] > -1e-3);
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let b = Matrix::random_normal(9, 9, &mut rng);
        let a = matmul(&b, Trans::Yes, &b, Trans::No);
        let (_, v) = sym_eig(&a);
        let vtv = matmul(&v, Trans::Yes, &v, Trans::No);
        assert!(vtv.rel_error(&Matrix::identity(9)) < 1e-4);
    }

    #[test]
    fn leading_eigvecs_shape() {
        let a = Matrix::identity(5);
        let v = leading_eigvecs(&a, 2);
        assert_eq!((v.rows(), v.cols()), (5, 2));
    }

    #[test]
    fn low_rank_structure_detected() {
        // Rank-2 Gram matrix: 3rd eigenvalue ≈ 0.
        let mut rng = Xoshiro256::seed_from_u64(12);
        let b = Matrix::random_normal(2, 6, &mut rng);
        let a = matmul(&b, Trans::Yes, &b, Trans::No); // 6×6 rank ≤ 2
        let (w, _) = sym_eig(&a);
        assert!(w[2].abs() < 1e-3, "w={w:?}");
    }
}
