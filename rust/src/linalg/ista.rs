//! ISTA — Iterative Shrinkage-Thresholding for `L1`-regularized least
//! squares: `min_x ½‖A·x − b‖² + λ‖x‖₁`.
//!
//! §IV-D of the paper replaces the second recovery stage (`UAΠΣ → AΠΣ`)
//! with an L1-constrained solve because the compressed-sensing map `U` is
//! sparse and the factor columns of a CP model are typically compressible.
//! ISTA with backtracking-free fixed step `1/L` (`L = ‖AᵀA‖₂` upper-bounded
//! by its Frobenius norm) is simple and adequate at these sizes.

use super::matmul::{matmul, Trans};
use super::matrix::Matrix;

/// Options for [`ista_l1`].
#[derive(Clone, Debug)]
pub struct IstaOptions {
    pub lambda: f32,
    pub max_iters: usize,
    pub tol: f32,
}

impl Default for IstaOptions {
    fn default() -> Self {
        Self {
            lambda: 1e-3,
            max_iters: 500,
            tol: 1e-7,
        }
    }
}

#[inline]
fn soft_threshold(x: f32, t: f32) -> f32 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

/// Solves `min_X ½‖A·X − B‖_F² + λ‖X‖₁` column-wise with FISTA momentum.
/// Returns the estimate and the iteration count actually used.
pub fn ista_l1(a: &Matrix, b: &Matrix, opts: &IstaOptions) -> (Matrix, usize) {
    let n = a.cols();
    let rhs_cols = b.cols();
    let ata = matmul(a, Trans::Yes, a, Trans::No);
    let atb = matmul(a, Trans::Yes, b, Trans::No);
    // Lipschitz bound: ‖AᵀA‖₂ ≤ ‖AᵀA‖_F.
    let lip = (ata.frobenius_norm() as f32).max(1e-12);
    let step = 1.0 / lip;

    let mut x = Matrix::zeros(n, rhs_cols);
    let mut y = x.clone();
    let mut t = 1.0f32;
    let mut iters = 0;
    for it in 0..opts.max_iters {
        iters = it + 1;
        // grad = AᵀA·y − AᵀB
        let mut grad = atb.clone();
        gemm_sym(&ata, &y, &mut grad); // grad = AᵀA·y − AᵀB
        // x_next = soft(y − step·grad, step·λ)
        let mut x_next = Matrix::zeros(n, rhs_cols);
        let thresh = step * opts.lambda;
        let mut max_delta = 0.0f32;
        for j in 0..rhs_cols {
            for i in 0..n {
                let v = soft_threshold(y.get(i, j) - step * grad.get(i, j), thresh);
                max_delta = max_delta.max((v - x.get(i, j)).abs());
                x_next.set(i, j, v);
            }
        }
        // FISTA momentum.
        let t_next = (1.0 + (1.0 + 4.0 * t * t).sqrt()) / 2.0;
        let beta = (t - 1.0) / t_next;
        let mut y_next = Matrix::zeros(n, rhs_cols);
        for j in 0..rhs_cols {
            for i in 0..n {
                let xn = x_next.get(i, j);
                y_next.set(i, j, xn + beta * (xn - x.get(i, j)));
            }
        }
        x = x_next;
        y = y_next;
        t = t_next;
        if max_delta < opts.tol {
            break;
        }
    }
    (x, iters)
}

/// `out ← G·y − out` specialized helper (G symmetric): computes the gradient
/// `G·y − AᵀB` given `out` pre-loaded with `AᵀB`.
fn gemm_sym(g: &Matrix, y: &Matrix, out: &mut Matrix) {
    let gy = matmul(g, Trans::No, y, Trans::No);
    for j in 0..out.cols() {
        for i in 0..out.rows() {
            out.set(i, j, gy.get(i, j) - out.get(i, j));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }

    #[test]
    fn recovers_sparse_signal() {
        // Compressed sensing: m=40 measurements of an n=80 signal with 5
        // nonzeros; a Gaussian A satisfies RIP whp at this ratio.
        let mut rng = Xoshiro256::seed_from_u64(40);
        let (m, n) = (40, 80);
        let a = Matrix::random_normal(m, n, &mut rng);
        let mut x_true = Matrix::zeros(n, 1);
        for &i in &[3usize, 17, 42, 55, 71] {
            x_true.set(i, 0, rng.next_gaussian() as f32 * 2.0 + 1.0);
        }
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let (x, _) = ista_l1(
            &a,
            &b,
            &IstaOptions {
                lambda: 1e-3,
                max_iters: 4000,
                tol: 1e-9,
            },
        );
        let err = x.rel_error(&x_true);
        assert!(err < 0.08, "rel err {err}"); // FISTA bias at this lambda
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let mut rng = Xoshiro256::seed_from_u64(41);
        let a = Matrix::random_normal(10, 6, &mut rng);
        let b = Matrix::zeros(10, 1);
        let (x, iters) = ista_l1(&a, &b, &IstaOptions::default());
        assert!(x.max_abs() < 1e-6);
        assert!(iters <= 500);
    }

    #[test]
    fn large_lambda_kills_solution() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        let a = Matrix::random_normal(20, 10, &mut rng);
        let b = Matrix::random_normal(20, 1, &mut rng);
        let (x, _) = ista_l1(
            &a,
            &b,
            &IstaOptions {
                lambda: 1e6,
                max_iters: 100,
                tol: 1e-9,
            },
        );
        assert_eq!(x.max_abs(), 0.0);
    }

    #[test]
    fn multiple_rhs_columns() {
        let mut rng = Xoshiro256::seed_from_u64(43);
        let a = Matrix::random_normal(30, 15, &mut rng);
        let x_true = Matrix::random_normal(15, 3, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let (x, _) = ista_l1(
            &a,
            &b,
            &IstaOptions {
                lambda: 1e-4,
                max_iters: 3000,
                tol: 1e-9,
            },
        );
        // Dense x_true: with tiny lambda this approaches plain LS.
        assert!(x.rel_error(&x_true) < 0.05, "err={}", x.rel_error(&x_true));
    }
}
