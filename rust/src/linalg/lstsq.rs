//! Least-squares front door.
//!
//! [`lstsq`] solves `min ‖A·X − B‖_F` choosing between the normal equations
//! (fast: one `n×n` Cholesky — the default for the well-conditioned stacked
//! recovery solve of Eq. (4)) and a QR fallback when the Gram matrix is
//! ill-conditioned.  [`ridge_solve`] adds Tikhonov damping for the ALS
//! updates where factor Grams can be nearly singular.

use super::cholesky::cholesky_solve;
use super::matmul::{matmul, Trans};
use super::matrix::Matrix;
use super::qr::qr_solve;
use anyhow::Result;

/// Solves `min ‖A·X − B‖_F` for `A (m×n, m ≥ n)`.
///
/// Strategy: form the normal equations `AᵀA·X = AᵀB`; if Cholesky reports a
/// non-PD pivot or the result contains non-finite values, fall back to
/// Householder QR on the full system.
pub fn lstsq(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    let ata = matmul(a, Trans::Yes, a, Trans::No);
    let atb = matmul(a, Trans::Yes, b, Trans::No);
    match cholesky_solve(&ata, &atb) {
        Ok(x) if x.data().iter().all(|v| v.is_finite()) => Ok(x),
        _ => qr_solve(a, b),
    }
}

/// Solves `(G + λ·mean(diag(G))·I)·X = B` for symmetric `G` — the damped
/// Gram solve used inside ALS (Alg. 1 line 3).
pub fn ridge_solve(g: &Matrix, b: &Matrix, lambda: f32) -> Result<Matrix> {
    let n = g.rows();
    let tr: f32 = (0..n).map(|i| g.get(i, i)).sum();
    let damp = lambda * tr / n as f32;
    let mut gd = g.clone();
    for i in 0..n {
        gd.add_assign_at(i, i, damp);
    }
    cholesky_solve(&gd, b)
}

/// Pseudo-inverse of a small full-column-rank matrix via `(AᵀA)⁻¹Aᵀ`.
pub fn pinv(a: &Matrix) -> Result<Matrix> {
    let ata = matmul(a, Trans::Yes, a, Trans::No);
    let at = a.transpose();
    cholesky_solve(&ata, &at)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn lstsq_well_conditioned() {
        let mut rng = Xoshiro256::seed_from_u64(20);
        let a = Matrix::random_normal(50, 10, &mut rng);
        let x_true = Matrix::random_normal(10, 3, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let x = lstsq(&a, &b).unwrap();
        assert!(x.rel_error(&x_true) < 1e-3);
    }

    #[test]
    fn lstsq_falls_back_on_rank_deficiency() {
        // Duplicate columns make AᵀA singular; jittered Cholesky still
        // produces a finite minimizer, or QR path errors — either way we
        // must not return NaNs.
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, 3.0]]);
        let b = Matrix::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        if let Ok(x) = lstsq(&a, &b) {
            assert!(x.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn ridge_solve_damps_singular_gram() {
        let g = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let b = Matrix::from_rows(&[&[2.0], &[2.0]]);
        let x = ridge_solve(&g, &b, 1e-3).unwrap();
        assert!(x.data().iter().all(|v| v.is_finite()));
        // symmetric problem → symmetric solution
        assert!((x.get(0, 0) - x.get(1, 0)).abs() < 1e-3);
    }

    #[test]
    fn pinv_inverts_orthval() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        let a = Matrix::random_normal(20, 6, &mut rng);
        let p = pinv(&a).unwrap();
        let pa = matmul(&p, Trans::No, &a, Trans::No);
        assert!(pa.rel_error(&Matrix::identity(6)) < 1e-3);
    }

    #[test]
    fn lstsq_multiple_rhs() {
        let mut rng = Xoshiro256::seed_from_u64(22);
        let a = Matrix::random_normal(30, 8, &mut rng);
        let x_true = Matrix::random_normal(8, 5, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let x = lstsq(&a, &b).unwrap();
        assert_eq!((x.rows(), x.cols()), (8, 5));
        assert!(x.rel_error(&x_true) < 1e-3);
    }
}
