//! Dense linear-algebra substrate.
//!
//! Everything the Exascale-Tensor pipeline needs, written against a
//! column-major [`Matrix`] type (column-major is the paper's §IV-A storage
//! choice: mode-1 unfoldings are then free).  No BLAS — the blocked GEMM in
//! [`matmul`] is the CPU-baseline hot path and is profiled in
//! EXPERIMENTS.md §Perf.
//!
//! Hot callers do not use the free functions directly: the [`backend`]
//! module wraps this surface in the [`ComputeBackend`] trait (serial
//! reference, multi-threaded CPU, XLA), and every pipeline stage above
//! `linalg` dispatches through a [`BackendHandle`].

pub mod backend;
pub mod cholesky;
pub mod eig;
pub mod hungarian;
pub mod ista;
pub mod iterative;
pub mod lstsq;
pub mod matmul;
pub mod matrix;
pub mod products;
pub mod qr;
pub mod svd;

pub use backend::{
    cpu_backend, mttkrp_materialized, serial_backend, BackendHandle, ComputeBackend,
    CpuParallelBackend, SerialBackend,
};
pub use cholesky::{cholesky_factor, cholesky_solve};
pub use eig::sym_eig;
pub use hungarian::{hungarian_max, hungarian_min, Assignment};
pub use ista::ista_l1;
pub use iterative::{cg_normal_solve, normal_damp, CgOptions, CgOutcome};
pub use lstsq::{lstsq, pinv, ridge_solve};
pub use matmul::{gemm, matmul, matvec, mttkrp_fused, mttkrp_fused_acc, Trans};
pub use matrix::Matrix;
pub use products::{hadamard, khatri_rao, kronecker};
pub use qr::{qr_decompose, qr_solve};
pub use svd::{leading_singular_vectors, svd_thin, Svd};
