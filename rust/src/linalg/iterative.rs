//! Matrix-free iterative least-squares: preconditioned conjugate gradient
//! on the (ridge-damped) normal equations — CGNR.
//!
//! The stacked recovery solve of Eq. (4) is `min ‖A·X − B‖_F` with
//! `A ((P·L)×I)` never materialized: the tiered `MapSource` can synthesize
//! any `L×w` panel of it on demand.  This module supplies the solver half
//! of that bargain: [`cg_normal_solve`] needs only a closure computing
//! `y ← AᵀA·x` (two streamed panel passes for the caller) plus the Gram
//! diagonal (one panel pass: column norms²), so the `I×I` Gram itself is
//! never formed and solver memory is `O(I)` per right-hand side.
//!
//! Conditioning is handled the same way the dense path handles it:
//! a Tikhonov ridge `damp = max(damp_rel · tr(AᵀA)/n, 1e-10)` — the exact
//! jitter `cholesky_factor` applies on a non-PD pivot — so the iterative
//! and Cholesky solvers agree to solver tolerance even on rank-deficient
//! systems (differential-tested in `coordinator/recovery.rs`).  The Jacobi
//! preconditioner `M = diag(AᵀA) + damp` costs nothing extra (the diagonal
//! is already required for the damp) and collapses the iteration count on
//! the badly row-scaled systems sketching produces.

use super::matrix::Matrix;
use anyhow::{ensure, Result};

/// Knobs for [`cg_normal_solve`].
#[derive(Clone, Copy, Debug)]
pub struct CgOptions {
    /// Relative ridge: `damp = max(damp_rel · tr(AᵀA)/n, 1e-10)`.  The
    /// default `1e-6` matches `cholesky_factor`'s non-PD jitter so the two
    /// solvers regularize identically.
    pub damp_rel: f32,
    /// Convergence: stop column `j` when `‖r‖ ≤ tol·‖bⱼ‖` (with
    /// `r = bⱼ − (AᵀA + damp·I)·x`).
    pub tol: f32,
    /// Per-column iteration cap; `0` means `2·n + 32`.
    pub max_iters: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        Self { damp_rel: 1e-6, tol: 1e-6, max_iters: 0 }
    }
}

/// What [`cg_normal_solve`] produced.
#[derive(Debug)]
pub struct CgOutcome {
    /// The `n×k` solution.
    pub x: Matrix,
    /// Iterations summed over all `k` right-hand sides (the
    /// `recovery_cg_iters` gauge).
    pub iterations: u64,
    /// Every column reached `tol` before its iteration cap.  A `false`
    /// outcome still carries the best iterate — callers decide whether
    /// that is fatal.
    pub converged: bool,
}

/// Ridge damping derived from the Gram diagonal, matching the Cholesky
/// jitter rule `max(damp_rel · tr/n, 1e-10)` (trace accumulated in f64
/// like `cholesky_factor` does).
pub fn normal_damp(diag: &[f32], damp_rel: f32) -> f32 {
    let n = diag.len().max(1);
    let tr: f64 = diag.iter().map(|&d| d as f64).sum();
    (damp_rel as f64 * tr / n as f64).max(1e-10) as f32
}

/// f64-accumulated dot product: CG's recurrences are sensitive to rounding
/// in the scalars even when the vectors stay f32.
fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Preconditioned CG on the damped normal equations:
/// solves `(AᵀA + damp·I)·X = B` column by column, where `AᵀA` is reached
/// only through `apply` (`y ← AᵀA·x`, caller-owned, typically two streamed
/// panel passes) and `diag` is its diagonal.
///
/// `x0`, when given, warm-starts every column (the sketch-and-solve polish
/// path); its shape must match the solution.  Breakdown (a non-positive
/// curvature `pᵀq`, impossible for an exactly-damped SPD operator but
/// reachable through f32 rounding) stops that column at its best iterate
/// rather than erroring.
pub fn cg_normal_solve(
    apply: &mut impl FnMut(&[f32], &mut [f32]),
    diag: &[f32],
    rhs: &Matrix,
    x0: Option<&Matrix>,
    opts: &CgOptions,
) -> Result<CgOutcome> {
    let n = diag.len();
    let k = rhs.cols();
    ensure!(rhs.rows() == n, "rhs rows {} != system size {}", rhs.rows(), n);
    if let Some(w) = x0 {
        ensure!(
            w.rows() == n && w.cols() == k,
            "warm start {}×{} does not match solution {}×{}",
            w.rows(),
            w.cols(),
            n,
            k
        );
    }
    let damp = normal_damp(diag, opts.damp_rel);
    // Jacobi preconditioner: damped-Gram diagonal, guarded so a zero
    // column (exactly rank-deficient A) degrades to the identity there
    // instead of poisoning the solve.
    let m_inv: Vec<f32> = diag
        .iter()
        .map(|&d| {
            let v = d + damp;
            if v.is_finite() && v > 0.0 {
                1.0 / v
            } else {
                1.0
            }
        })
        .collect();
    let max_iters = if opts.max_iters == 0 { 2 * n + 32 } else { opts.max_iters };

    let mut x = Matrix::zeros(n, k);
    let mut iterations: u64 = 0;
    let mut converged = true;
    let mut q = vec![0.0f32; n];
    let mut r = vec![0.0f32; n];
    let mut z = vec![0.0f32; n];
    let mut p = vec![0.0f32; n];
    for j in 0..k {
        let b = rhs.col(j);
        let bnorm = dot(b, b).sqrt();
        if bnorm == 0.0 {
            continue; // zero RHS → zero solution, exactly
        }
        let xj = x.col_mut(j);
        if let Some(w) = x0 {
            xj.copy_from_slice(w.col(j));
            apply(xj, &mut q);
            for i in 0..n {
                r[i] = b[i] - q[i] - damp * xj[i];
            }
        } else {
            r.copy_from_slice(b);
        }
        for i in 0..n {
            z[i] = m_inv[i] * r[i];
        }
        p.copy_from_slice(&z);
        let mut rz = dot(&r, &z);
        let stop = opts.tol as f64 * bnorm;
        for _ in 0..max_iters {
            if dot(&r, &r).sqrt() <= stop {
                break;
            }
            apply(&p, &mut q);
            for i in 0..n {
                q[i] += damp * p[i];
            }
            let pq = dot(&p, &q);
            if !(pq.is_finite() && pq > 0.0) {
                break; // rounding breakdown: keep the best iterate
            }
            let alpha = rz / pq;
            for i in 0..n {
                xj[i] += (alpha * p[i] as f64) as f32;
                r[i] -= (alpha * q[i] as f64) as f32;
            }
            for i in 0..n {
                z[i] = m_inv[i] * r[i];
            }
            let rz_new = dot(&r, &z);
            let beta = rz_new / rz;
            rz = rz_new;
            for i in 0..n {
                p[i] = z[i] + (beta * p[i] as f64) as f32;
            }
            iterations += 1;
        }
        if dot(&r, &r).sqrt() > stop {
            converged = false;
        }
    }
    Ok(CgOutcome { x, iterations, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, matvec, Trans};
    use crate::linalg::cholesky::cholesky_solve;
    use crate::util::rng::Xoshiro256;

    /// Dense reference operator: y ← AᵀA·x via two matvecs (what the
    /// streamed panel passes compute without materializing AᵀA).
    fn dense_apply(a: &Matrix) -> impl FnMut(&[f32], &mut [f32]) + '_ {
        move |x, y| {
            let ax = matvec(a, Trans::No, x);
            y.copy_from_slice(&matvec(a, Trans::Yes, &ax));
        }
    }

    fn gram_diag(a: &Matrix) -> Vec<f32> {
        (0..a.cols())
            .map(|j| a.col(j).iter().map(|&v| v * v).sum())
            .collect()
    }

    /// The dense oracle with the *same* ridge: `(AᵀA + damp·I)⁻¹·AᵀB`.
    fn ridge_cholesky(a: &Matrix, atb: &Matrix, damp: f32) -> Matrix {
        let mut gram = matmul(a, Trans::Yes, a, Trans::No);
        for i in 0..gram.rows() {
            gram.add_assign_at(i, i, damp);
        }
        cholesky_solve(&gram, atb).unwrap()
    }

    #[test]
    fn cg_matches_lstsq_well_conditioned() {
        let mut rng = Xoshiro256::seed_from_u64(60);
        let a = Matrix::random_normal(120, 24, &mut rng);
        let x_true = Matrix::random_normal(24, 3, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let atb = matmul(&a, Trans::Yes, &b, Trans::No);
        let diag = gram_diag(&a);
        let out = cg_normal_solve(
            &mut dense_apply(&a),
            &diag,
            &atb,
            None,
            &CgOptions::default(),
        )
        .unwrap();
        assert!(out.converged);
        assert!(out.iterations > 0);
        // The ridge bounds accuracy at ~damp_rel, not machine epsilon.
        assert!(out.x.rel_error(&x_true) < 1e-3, "rel {}", out.x.rel_error(&x_true));
    }

    #[test]
    fn cg_matches_ridge_cholesky_on_rank_deficient_system() {
        // Duplicate column → exactly singular Gram.  Both solvers fall
        // back on the identical ridge, so they must agree tightly.
        let mut rng = Xoshiro256::seed_from_u64(61);
        let base = Matrix::random_normal(80, 11, &mut rng);
        let a = Matrix::from_fn(80, 12, |i, j| {
            if j < 11 {
                base.get(i, j)
            } else {
                base.get(i, 0) // copy of column 0
            }
        });
        let b = Matrix::random_normal(80, 2, &mut rng);
        let atb = matmul(&a, Trans::Yes, &b, Trans::No);
        let diag = gram_diag(&a);
        let opts = CgOptions::default();
        let damp = normal_damp(&diag, opts.damp_rel);
        let oracle = ridge_cholesky(&a, &atb, damp);
        let out =
            cg_normal_solve(&mut dense_apply(&a), &diag, &atb, None, &opts).unwrap();
        assert!(out.x.data().iter().all(|v| v.is_finite()));
        assert!(
            out.x.rel_error(&oracle) < 1e-3,
            "cg vs ridge-cholesky rel {}",
            out.x.rel_error(&oracle)
        );
    }

    #[test]
    fn cg_matches_ridge_cholesky_near_singular() {
        // Columns spanning 3 decades of scale: the Jacobi preconditioner
        // is what keeps the iteration count sane here.
        let mut rng = Xoshiro256::seed_from_u64(62);
        let base = Matrix::random_normal(90, 10, &mut rng);
        let a = Matrix::from_fn(90, 10, |i, j| {
            let scale = if j >= 7 { 1e-3 } else { 1.0 };
            base.get(i, j) * scale
        });
        let b = Matrix::random_normal(90, 2, &mut rng);
        let atb = matmul(&a, Trans::Yes, &b, Trans::No);
        let diag = gram_diag(&a);
        let opts = CgOptions::default();
        let damp = normal_damp(&diag, opts.damp_rel);
        let oracle = ridge_cholesky(&a, &atb, damp);
        let out =
            cg_normal_solve(&mut dense_apply(&a), &diag, &atb, None, &opts).unwrap();
        assert!(
            out.x.rel_error(&oracle) < 5e-3,
            "cg vs ridge-cholesky rel {}",
            out.x.rel_error(&oracle)
        );
    }

    #[test]
    fn warm_start_cuts_iterations() {
        let mut rng = Xoshiro256::seed_from_u64(63);
        let a = Matrix::random_normal(150, 30, &mut rng);
        let x_true = Matrix::random_normal(30, 2, &mut rng);
        let b = matmul(&a, Trans::No, &x_true, Trans::No);
        let atb = matmul(&a, Trans::Yes, &b, Trans::No);
        let diag = gram_diag(&a);
        let opts = CgOptions::default();
        let cold =
            cg_normal_solve(&mut dense_apply(&a), &diag, &atb, None, &opts).unwrap();
        // Warm start from a mildly perturbed truth (what the sketch
        // hand-off looks like) must converge in fewer iterations.
        let warm0 = Matrix::from_fn(30, 2, |i, j| x_true.get(i, j) * 1.001);
        let warm =
            cg_normal_solve(&mut dense_apply(&a), &diag, &atb, Some(&warm0), &opts)
                .unwrap();
        assert!(warm.converged);
        assert!(
            warm.iterations < cold.iterations,
            "warm {} !< cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!(warm.x.rel_error(&x_true) < 1e-3);
    }

    #[test]
    fn zero_rhs_and_shape_checks() {
        let a = Matrix::from_fn(10, 4, |i, j| (i + j) as f32 / 10.0);
        let diag = gram_diag(&a);
        let zero = Matrix::zeros(4, 2);
        let out = cg_normal_solve(
            &mut dense_apply(&a),
            &diag,
            &zero,
            None,
            &CgOptions::default(),
        )
        .unwrap();
        assert_eq!(out.iterations, 0);
        assert!(out.x.data().iter().all(|&v| v == 0.0));
        let bad = Matrix::zeros(5, 2);
        assert!(cg_normal_solve(
            &mut dense_apply(&a),
            &diag,
            &bad,
            None,
            &CgOptions::default()
        )
        .is_err());
    }

    #[test]
    fn damp_matches_cholesky_jitter_rule() {
        let diag = vec![2.0f32, 4.0, 6.0];
        // tr = 12, n = 3 → 1e-6 · 4 = 4e-6
        assert!((normal_damp(&diag, 1e-6) - 4e-6).abs() < 1e-12);
        // Floor kicks in on a zero trace.
        assert_eq!(normal_damp(&[0.0, 0.0], 1e-6), 1e-10);
    }
}
