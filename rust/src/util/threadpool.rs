//! Scoped worker pool (offline substitute for `rayon`).
//!
//! The coordinator uses this for the two parallel stages of Fig. 2:
//! block-level compression (independent tensor blocks) and replica-level
//! decomposition (independent proxy tensors).  Jobs are closures pushed to a
//! shared queue; `scope` blocks until all submitted jobs complete and
//! propagates the first panic.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// A pool of `n` OS threads with a shared FIFO job queue.
pub struct ThreadPool {
    size: usize,
}

impl ThreadPool {
    /// A pool that will run scopes on `size.max(1)` threads.
    pub fn new(size: usize) -> Self {
        Self { size: size.max(1) }
    }

    /// Pool sized by [`crate::util::default_threads`].
    pub fn default_sized() -> Self {
        Self::new(crate::util::default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Runs `f` with a [`Scope`] that accepts jobs borrowing from the caller's
    /// stack; returns once every submitted job has finished.  Panics if any
    /// job panicked.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'env>) -> R,
    {
        let (tx, rx) = mpsc::channel::<Job<'env>>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let scope = Scope {
            tx: Some(tx),
            pending: Arc::new(AtomicUsize::new(0)),
        };

        let result = thread::scope(|s| {
            for _ in 0..self.size {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                let pending = Arc::clone(&scope.pending);
                s.spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panics.fetch_add(1, Ordering::SeqCst);
                            }
                            pending.fetch_sub(1, Ordering::SeqCst);
                        }
                        Err(_) => break, // channel closed: scope is done
                    }
                });
            }
            let r = f(&scope);
            // Dropping the sender closes the queue; workers drain it and exit.
            drop(scope);
            r
        });

        let n = panics.load(Ordering::SeqCst);
        if n > 0 {
            panic!("{n} pool job(s) panicked");
        }
        result
    }

    /// Scoped parallel iteration over `0..n` in contiguous chunks: runs
    /// `f(range)` for a balanced partition of the index range, blocking
    /// until every chunk completes.
    ///
    /// This is the shared chunking primitive for the streaming stages
    /// (block grids in `compress::stream`, `coordinator::refine`) and the
    /// strip-parallel kernels in `linalg::backend` — call sites used to
    /// hand-roll per-item spawn loops.  Chunks are at least `min_chunk`
    /// indices wide (clamped to ≥ 1); when a single chunk covers
    /// everything, `f` runs inline without touching the pool.
    pub fn for_each_chunk<F>(&self, n: usize, min_chunk: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let min_chunk = min_chunk.max(1);
        // ~2 chunks per worker smooths imbalance without oversubmitting;
        // never so many that a chunk drops below `min_chunk`.
        let target_chunks = (self.size * 2).max(1);
        let parts = target_chunks.min(n / min_chunk).max(1);
        let ranges = Self::partition(n, parts);
        if ranges.len() <= 1 {
            f(0..n);
            return;
        }
        self.scope(|scope| {
            for (start, end) in ranges {
                let f = &f;
                scope.spawn(move || f(start..end));
            }
        });
    }

    /// Runs `n.max(1)` scoped worker threads, each executing `f(worker)`,
    /// and returns when all finish (panics propagate via `thread::scope`).
    ///
    /// Unlike [`ThreadPool::scope`], the thread count is an explicit
    /// argument rather than the pool size: the streaming engine
    /// (`compress::engine`) sizes its I/O-producer and compute-consumer
    /// groups independently, and routing both groups through one queue-fed
    /// pool could deadlock (producers occupying every pool thread would
    /// starve the consumers they block on).
    pub fn run_workers<F>(n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let n = n.max(1);
        thread::scope(|s| {
            for w in 0..n {
                let f = &f;
                s.spawn(move || f(w));
            }
        });
    }

    /// Balanced contiguous partition of `0..n` into at most `parts`
    /// non-empty ranges (earlier ranges at most one index longer) — the
    /// shared chunking primitive behind [`ThreadPool::for_each_chunk`] and
    /// the strip-split kernels in `linalg::backend`.
    pub fn partition(n: usize, parts: usize) -> Vec<(usize, usize)> {
        let parts = parts.clamp(1, n.max(1));
        let base = n / parts;
        let rem = n % parts;
        let mut out = Vec::with_capacity(parts);
        let mut start = 0;
        for p in 0..parts {
            let len = base + usize::from(p < rem);
            if len == 0 {
                break;
            }
            out.push((start, start + len));
            start += len;
        }
        out
    }

    /// Parallel map over an index range: runs `f(i)` for `i in 0..n` and
    /// collects results in order.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots: Vec<(usize, &mut Option<T>)> = out.iter_mut().enumerate().collect();
            self.scope(|scope| {
                for (i, slot) in slots {
                    let f = &f;
                    scope.spawn(move || {
                        *slot = Some(f(i));
                    });
                }
            });
        }
        out.into_iter().map(|o| o.expect("job did not run")).collect()
    }
}

/// Handle for submitting jobs inside [`ThreadPool::scope`].
pub struct Scope<'env> {
    tx: Option<mpsc::Sender<Job<'env>>>,
    pending: Arc<AtomicUsize>,
}

impl<'env> Scope<'env> {
    /// Submits a job; it may run on any pool thread.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("scope already closed")
            .send(Box::new(f))
            .expect("pool workers gone");
    }
}

impl<'env> Drop for Scope<'env> {
    fn drop(&mut self) {
        self.tx.take(); // close the queue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = AtomicU64::new(0);
        pool.scope(|s| {
            for i in 0..100u64 {
                let counter = &counter;
                s.spawn(move || {
                    counter.fetch_add(i, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn map_indexed_ordered() {
        let pool = ThreadPool::new(3);
        let v = pool.map_indexed(50, |i| i * i);
        assert_eq!(v, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn borrows_from_stack() {
        let pool = ThreadPool::new(2);
        let data = vec![1usize, 2, 3, 4];
        let sum = AtomicUsize::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(2) {
                let sum = &sum;
                s.spawn(move || {
                    sum.fetch_add(chunk.iter().sum::<usize>(), Ordering::SeqCst);
                });
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic(expected = "pool job(s) panicked")]
    fn propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("boom"));
        });
    }

    #[test]
    fn for_each_chunk_covers_range_exactly_once() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 2, 7, 64, 101] {
            for min_chunk in [1usize, 4, 1000] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                pool.for_each_chunk(n, min_chunk, |range| {
                    for i in range {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    }
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                    "n={n} min_chunk={min_chunk}"
                );
            }
        }
    }

    #[test]
    fn for_each_chunk_respects_min_chunk() {
        let pool = ThreadPool::new(4);
        let max_calls = std::sync::atomic::AtomicUsize::new(0);
        pool.for_each_chunk(100, 40, |range| {
            assert!(range.len() >= 40 || range.end == 100);
            max_calls.fetch_add(1, Ordering::SeqCst);
        });
        assert!(max_calls.load(Ordering::SeqCst) <= 3);
    }

    #[test]
    fn run_workers_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..6).map(|_| AtomicUsize::new(0)).collect();
        ThreadPool::run_workers(6, |w| {
            hits[w].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        // Zero clamps to one worker.
        let ran = AtomicUsize::new(0);
        ThreadPool::run_workers(0, |w| {
            assert_eq!(w, 0);
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let v = pool.map_indexed(10, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn nested_scopes() {
        let pool = ThreadPool::new(2);
        let outer = AtomicUsize::new(0);
        pool.scope(|s| {
            let outer = &outer;
            s.spawn(move || {
                outer.fetch_add(1, Ordering::SeqCst);
            });
        });
        pool.scope(|s| {
            let outer = &outer;
            s.spawn(move || {
                outer.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(outer.load(Ordering::SeqCst), 2);
    }
}
