//! Minimal JSON parser/serializer (offline substitute for `serde_json`).
//!
//! Only used on control paths: reading `artifacts/manifest.json`, writing
//! benchmark reports, and run configs.  Supports the full JSON grammar with
//! the usual Rust-side conveniences but no zero-copy tricks — files here are
//! a few KB.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — benchmark reports diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // ---------- construction helpers ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------- parsing ----------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------- serialization ----------

    /// Compact single-line form.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty form with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 1; // past the first 'u' escape's last digit
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    if self.peek() != Some(b'u') {
                                        return Err(self.err("expected low surrogate"));
                                    }
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Reads the 4 hex digits following `\u` (cursor on 'u').
    fn hex4(&mut self) -> Result<u32, JsonError> {
        let start = self.pos + 1;
        if start + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[start..start + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos = start + 3; // caller advances one more past the final digit
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"dims":[100,200,300],"name":"compress_block","dtype":"f32","ok":true,"scale":0.125}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::obj(vec![
            ("arr", Json::arr_usize(&[1, 2, 3])),
            ("s", Json::str("x\"y")),
            ("empty", Json::Arr(vec![])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
        assert_eq!(Json::Num(7.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
