//! Timing and summary statistics used by the bench harness and the
//! coordinator's per-stage metrics.

use std::time::{Duration, Instant};

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Collects raw samples so percentiles can be computed (bench harness).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Percentile by linear interpolation; `q` in `[0, 1]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let frac = pos - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

/// RAII-ish stage timer: `Timer::start()` … `elapsed_ms()`.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Formats a duration in engineering units (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_empty_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(1.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(0.95) - 95.05).abs() < 0.1);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(3.5e-9).contains("ns"));
        assert!(fmt_duration(3.5e-6).contains("µs"));
        assert!(fmt_duration(3.5e-3).contains("ms"));
        assert!(fmt_duration(3.5).contains("s"));
    }

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
