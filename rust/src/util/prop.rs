//! Mini property-based testing harness (offline substitute for `proptest`).
//!
//! A property is a closure over a [`Gen`] that panics on violation. The
//! runner executes `cases` seeded cases; on failure it reports the case seed
//! so the exact counterexample replays with `check_one`.  No shrinking —
//! generators are kept small instead (the proptest style of "grow inputs,
//! shrink failures" is replaced by "sample small structured inputs").

use crate::util::rng::Xoshiro256;

/// Per-case random source handed to properties.
pub struct Gen {
    pub rng: Xoshiro256,
    /// Size hint: generators should keep dimensions ≤ roughly this.
    pub size: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Standard-normal f32 vector of length `n`.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.gaussian_vec_f32(n)
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut p);
        p
    }

    /// True with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }
}

/// Runs `cases` random cases of `prop`, panicking with the failing case seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen)) {
    // Base seed derived from the property name so distinct properties explore
    // distinct inputs but remain fully deterministic run-to-run.
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3));
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Xoshiro256::seed_from_u64(seed),
                size: 16,
            };
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case} (replay: check_one(\"{name}\", {seed:#x}, prop)): {msg}"
            );
        }
    }
}

/// Replays a single case by seed — paste the seed from a failure report.
pub fn check_one(_name: &str, seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen {
        rng: Xoshiro256::seed_from_u64(seed),
        size: 16,
    };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check("trivial", 25, |g| {
            let _ = g.int(0, 10);
        });
        // separate counter loop (closure above must be Fn, not FnMut)
        check("count-cases", 25, |_| {});
        count += 25;
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_name() {
        check("always-fails", 5, |_| panic!("nope"));
    }

    #[test]
    fn generators_respect_bounds() {
        check("gen-bounds", 100, |g| {
            let n = g.int(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f32(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&x));
            let p = g.permutation(n);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        check("det", 10, |g| {
            let _ = g.int(0, 1000);
        });
        // Capture explicitly with check_one for the same derived seeds.
        let base = "det"
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3));
        for case in 0..3u64 {
            let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut g = Gen {
                rng: Xoshiro256::seed_from_u64(seed),
                size: 16,
            };
            first.push(g.int(0, 1000));
        }
        let mut second: Vec<usize> = Vec::new();
        for case in 0..3u64 {
            let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut g = Gen {
                rng: Xoshiro256::seed_from_u64(seed),
                size: 16,
            };
            second.push(g.int(0, 1000));
        }
        assert_eq!(first, second);
    }
}
