//! Software half-precision conversion (offline substitute for the `half`
//! crate).
//!
//! Two 16-bit formats appear in the paper's mixed-precision scheme (§IV-B):
//! IEEE binary16 (`f16`, what GPU tensor cores multiply) and bfloat16
//! (`bf16`, what the TPU MXU multiplies — see DESIGN.md
//! §Hardware-Adaptation).  Both conversions round to nearest-even, matching
//! hardware behaviour, so the residual-splitting error analysis carries
//! over bit-for-bit.

/// IEEE 754 binary16 bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct F16(pub u16);

/// bfloat16 bit pattern (truncated-exponent f32).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Bf16(pub u16);

impl F16 {
    /// Converts `f32 → f16` with round-to-nearest-even, handling subnormals,
    /// overflow to infinity, and NaN payload preservation (quiet bit set).
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let mant = bits & 0x007F_FFFF;

        if exp == 0xFF {
            // Inf / NaN
            let payload = if mant != 0 { 0x0200 | ((mant >> 13) as u16 & 0x3FF) } else { 0 };
            return F16(sign | 0x7C00 | payload);
        }

        // Unbiased exponent; f16 bias is 15, f32 bias is 127.
        let unbiased = exp - 127;
        if unbiased > 15 {
            return F16(sign | 0x7C00); // overflow → ±inf
        }
        if unbiased >= -14 {
            // Normal range: round 23-bit mantissa to 10 bits, RNE.
            let e16 = (unbiased + 15) as u32;
            let mut m = mant >> 13;
            let round_bits = mant & 0x1FFF;
            if round_bits > 0x1000 || (round_bits == 0x1000 && (m & 1) == 1) {
                m += 1;
            }
            // Mantissa carry may bump the exponent (still fine: 0x7C00 = inf).
            let out = (e16 << 10).wrapping_add(m) as u16;
            return F16(sign | out);
        }
        if unbiased >= -25 {
            // Subnormal: shift in the implicit leading 1.
            let full = mant | 0x0080_0000;
            let shift = (-14 - unbiased + 13) as u32;
            let m = full >> shift;
            let rem = full & ((1u32 << shift) - 1);
            let half = 1u32 << (shift - 1);
            let mut m = m;
            if rem > half || (rem == half && (m & 1) == 1) {
                m += 1;
            }
            return F16(sign | m as u16);
        }
        F16(sign) // underflow → ±0
    }

    /// Converts `f16 → f32` exactly (every f16 is representable in f32).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let mant = (self.0 & 0x3FF) as u32;
        let bits = match (exp, mant) {
            (0, 0) => sign,
            (0, m) => {
                // Subnormal: value = m · 2⁻²⁴. Normalize: with p the index
                // of m's leading 1 (0-based), value = 2^(p−24)·(m/2^p), so
                // the f32 biased exponent is p − 24 + 127 = p + 103.
                let p = 31 - m.leading_zeros(); // 0..=9
                let exp32 = p + 103;
                let m32 = (m << (10 - p)) & 0x3FF; // drop the implicit 1
                sign | (exp32 << 23) | (m32 << 13)
            }
            (0x1F, 0) => sign | 0x7F80_0000,
            (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
            (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
        };
        f32::from_bits(bits)
    }
}

impl Bf16 {
    /// Converts `f32 → bf16` with round-to-nearest-even (truncate the low 16
    /// mantissa bits with rounding), NaN made quiet.
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb) & !(round_bit - 1);
        Bf16((rounded >> 16) as u16)
    }

    /// Converts `bf16 → f32` exactly.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// Splits `x` into `(hi, lo)` where `hi = f16(x)` and `lo = x - hi` — the
/// first-order residual decomposition of Eq. (5) in the paper.
#[inline]
pub fn split_f16(x: f32) -> (f32, f32) {
    let hi = F16::from_f32(x).to_f32();
    (hi, if hi.is_finite() { x - hi } else { 0.0 })
}

/// bfloat16 analogue of [`split_f16`] (MXU path, DESIGN.md
/// §Hardware-Adaptation).
#[inline]
pub fn split_bf16(x: f32) -> (f32, f32) {
    let hi = Bf16::from_f32(x).to_f32();
    (hi, if hi.is_finite() { x - hi } else { 0.0 })
}

/// Rounds every element through f16 (simulates a lossy FP16 store).
pub fn quantize_f16_slice(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| F16::from_f32(x).to_f32()).collect()
}

/// Rounds every element through bf16.
pub fn quantize_bf16_slice(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| Bf16::from_f32(x).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF); // f16::MAX
        assert_eq!(F16::from_f32(1e6).0, 0x7C00); // overflow → inf
        assert_eq!(F16::from_f32(f32::INFINITY).0, 0x7C00);
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn f16_round_trip_exact_for_representables() {
        // All 2^16 patterns: to_f32 then from_f32 must be the identity for
        // non-NaN values.
        for bits in 0..=u16::MAX {
            let h = F16(bits);
            let f = h.to_f32();
            if f.is_nan() {
                assert!(F16::from_f32(f).to_f32().is_nan());
            } else {
                assert_eq!(F16::from_f32(f), h, "bits={bits:#06x} f={f}");
            }
        }
    }

    #[test]
    fn f16_subnormals() {
        let smallest = F16(0x0001).to_f32(); // 2^-24
        assert!((smallest - 5.960_464_5e-8).abs() < 1e-12);
        assert_eq!(F16::from_f32(smallest), F16(0x0001));
        // Below half the smallest subnormal → 0.
        assert_eq!(F16::from_f32(1e-9).0, 0x0000);
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 → rounds to even (1.0).
        let x = 1.0 + (2f32).powi(-11);
        assert_eq!(F16::from_f32(x).0, 0x3C00);
        // 1 + 3*2^-11 is between 1+2^-10 and 1+2^-9 → ties to even (1+2^-9).
        let y = 1.0 + 3.0 * (2f32).powi(-11);
        assert_eq!(F16::from_f32(y).0, 0x3C02);
    }

    #[test]
    fn bf16_known_values() {
        assert_eq!(Bf16::from_f32(1.0).0, 0x3F80);
        assert_eq!(Bf16::from_f32(-1.0).0, 0xBF80);
        assert_eq!(Bf16::from_f32(f32::INFINITY).0, 0x7F80);
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        // 3.14159 → nearest bf16
        let pi = Bf16::from_f32(std::f32::consts::PI).to_f32();
        assert!((pi - std::f32::consts::PI).abs() < 0.02);
    }

    #[test]
    fn bf16_round_trip_identity() {
        for bits in 0..=u16::MAX {
            let b = Bf16(bits);
            let f = b.to_f32();
            if f.is_nan() {
                assert!(Bf16::from_f32(f).to_f32().is_nan());
            } else {
                assert_eq!(Bf16::from_f32(f), b, "bits={bits:#06x}");
            }
        }
    }

    #[test]
    fn split_reconstructs_exactly() {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(11);
        for _ in 0..10_000 {
            let x = (rng.next_gaussian() * 10.0) as f32;
            let (hi, lo) = split_f16(x);
            // Sterbenz: hi within 2x of x ⇒ x - hi exact ⇒ hi + lo == x.
            assert_eq!(hi + lo, x, "x={x}");
            let (bhi, blo) = split_bf16(x);
            assert_eq!(bhi + blo, x, "x={x}");
        }
    }

    #[test]
    fn split_residual_is_small() {
        let (hi, lo) = split_f16(1.2345678);
        assert!(lo.abs() <= hi.abs() * (2f32).powi(-10));
        let (bhi, blo) = split_bf16(1.2345678);
        assert!(blo.abs() <= bhi.abs() * (2f32).powi(-7));
    }
}
