//! Deterministic fault injection.
//!
//! Failure paths are only trustworthy if they are *exercised*, and only
//! testable if the failures are reproducible.  This module provides a
//! process-global registry of named fault sites — `io_read`, `io_write`,
//! `checkpoint_commit`, `worker_panic`, `conn_stall` — that production code
//! probes at the moment the corresponding real failure could occur.  A probe
//! is a single relaxed atomic load when no plan is armed (the compiled-in
//! sites are inert by construction); when a [`FaultPlan`] is armed the probe
//! consults a schedule that is a pure function of the plan's seed, reusing
//! the `util/rng.rs` counter-keyed `mix64` discipline: the n-th probe of a
//! site faults iff
//!
//! ```text
//! n >= after  &&  (n - after) % period == offset(seed, site)  &&  fired < max
//! ```
//!
//! where `offset = counter_key(seed, site, ..) % period`.  The schedule is
//! strictly periodic, so for `period >= 2` two consecutive probes never both
//! fault — a retry loop with one spare attempt always eventually succeeds,
//! which is what makes "faulted run is bitwise identical to clean run"
//! assertable rather than merely probable.
//!
//! Sites can carry an optional `key` filter (e.g. a job's scheduler
//! sequence number) so chaos tests can aim `worker_panic` at one poison
//! job while other tenants run clean.
//!
//! Arming is test-scoped by default: [`arm_scoped`] holds a global mutex so
//! concurrently running `#[test]`s that arm plans serialize instead of
//! observing each other's faults, and disarms on drop.  The hidden
//! `--fault-plan` CLI flag uses [`arm`] (process-wide, never disarmed).

use crate::util::rng::counter_key;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Marker embedded in error messages to classify a failure as transient
/// (worth retrying).  The vendored `anyhow` shim is string-backed with no
/// downcast support, so classification is a message convention: producers
/// of retryable failures append the marker, and [`is_transient`] checks it
/// after `{:#}` context chaining.
pub const TRANSIENT_MARKER: &str = "(transient)";

/// True if a rendered error message carries the transient marker anywhere
/// in its context chain.
pub fn is_transient(msg: &str) -> bool {
    msg.contains(TRANSIENT_MARKER)
}

/// A named injection point.  Every variant corresponds to exactly one class
/// of real-world failure and one probe location in production code.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Site {
    /// A block read from a `FileTensorSource` fails transiently.
    IoRead,
    /// A tensor payload write fails mid-stream.
    IoWrite,
    /// The atomic rename committing a checkpoint generation fails.
    CheckpointCommit,
    /// A scheduler worker panics mid-job.
    WorkerPanic,
    /// An accepted connection stalls past its read deadline.
    ConnStall,
}

/// All sites, in probe-counter index order.
pub const ALL_SITES: [Site; 5] = [
    Site::IoRead,
    Site::IoWrite,
    Site::CheckpointCommit,
    Site::WorkerPanic,
    Site::ConnStall,
];

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::IoRead => "io_read",
            Site::IoWrite => "io_write",
            Site::CheckpointCommit => "checkpoint_commit",
            Site::WorkerPanic => "worker_panic",
            Site::ConnStall => "conn_stall",
        }
    }

    pub fn parse(s: &str) -> Option<Site> {
        ALL_SITES.iter().copied().find(|site| site.name() == s)
    }

    fn index(self) -> usize {
        match self {
            Site::IoRead => 0,
            Site::IoWrite => 1,
            Site::CheckpointCommit => 2,
            Site::WorkerPanic => 3,
            Site::ConnStall => 4,
        }
    }
}

/// Per-site schedule parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SiteSpec {
    /// Fault every `period`-th probe once the schedule starts (>= 1).
    /// `period >= 2` guarantees two consecutive probes never both fault.
    pub period: u64,
    /// Total fault budget for the site (`u64::MAX` = unbounded).
    pub max: u64,
    /// Probes to let through untouched before the schedule starts.
    pub after: u64,
    /// When set, only probes carrying this key are eligible to fault
    /// (unkeyed probes still advance the counter but never fire).
    pub key: Option<u64>,
}

impl Default for SiteSpec {
    fn default() -> Self {
        SiteSpec { period: 1, max: u64::MAX, after: 0, key: None }
    }
}

/// A seeded set of per-site schedules.  Pure data: arming it is what makes
/// probes consult it.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    sites: BTreeMap<Site, SiteSpec>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, sites: BTreeMap::new() }
    }

    /// Builder-style: add (or replace) one site's schedule.
    pub fn site(mut self, site: Site, spec: SiteSpec) -> Self {
        assert!(spec.period >= 1, "fault period must be >= 1");
        self.sites.insert(site, spec);
        self
    }

    pub fn spec(&self, site: Site) -> Option<&SiteSpec> {
        self.sites.get(&site)
    }

    /// Parse the `--fault-plan` flag syntax:
    ///
    /// ```text
    /// seed=42;io_read:period=6,max=3;worker_panic:max=2,key=7
    /// ```
    ///
    /// `seed=` is optional (defaults to 0); every other `;`-separated part
    /// is `<site>[:k=v,...]` with keys `period`, `max`, `after`, `key`.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new(0);
        for part in text.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(v) = part.strip_prefix("seed=") {
                plan.seed = v.parse().with_context(|| format!("bad fault seed '{v}'"))?;
                continue;
            }
            let (name, params) = match part.split_once(':') {
                Some((n, p)) => (n.trim(), p),
                None => (part, ""),
            };
            let site = Site::parse(name)
                .with_context(|| format!("unknown fault site '{name}'"))?;
            let mut spec = SiteSpec::default();
            for kv in params.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("bad fault param '{kv}' (want k=v)"))?;
                let val: u64 =
                    v.trim().parse().with_context(|| format!("bad fault value '{v}'"))?;
                match k.trim() {
                    "period" => spec.period = val,
                    "max" => spec.max = val,
                    "after" => spec.after = val,
                    "key" => spec.key = Some(val),
                    other => bail!("unknown fault param '{other}'"),
                }
            }
            if spec.period == 0 {
                bail!("fault site '{name}': period must be >= 1");
            }
            plan.sites.insert(site, spec);
        }
        if plan.sites.is_empty() {
            bail!("fault plan '{text}' names no sites");
        }
        Ok(plan)
    }
}

/// The armed plan plus its live counters.
struct Active {
    plan: FaultPlan,
    /// Deterministic per-site phase: `counter_key(seed, site, ..) % period`.
    offsets: [u64; ALL_SITES.len()],
    probes: [AtomicU64; ALL_SITES.len()],
    fired: [AtomicU64; ALL_SITES.len()],
}

impl Active {
    fn new(plan: FaultPlan) -> Self {
        let mut offsets = [0u64; ALL_SITES.len()];
        for site in ALL_SITES {
            if let Some(spec) = plan.sites.get(&site) {
                offsets[site.index()] =
                    counter_key(plan.seed, 0xFA17, site.index() as u64, 0, 0) % spec.period;
            }
        }
        Active {
            plan,
            offsets,
            probes: Default::default(),
            fired: Default::default(),
        }
    }
}

/// Fast-path gate: a single relaxed load on every probe.  Only `true` while
/// a plan is armed, so unarmed production runs pay one predictable branch.
static ARMED: AtomicBool = AtomicBool::new(false);
static ACTIVE: Mutex<Option<Arc<Active>>> = Mutex::new(None);
/// Serializes tests that arm plans (fault state is process-global).
static ARM_LOCK: Mutex<()> = Mutex::new(());

fn active() -> Option<Arc<Active>> {
    ACTIVE.lock().unwrap_or_else(|p| p.into_inner()).clone()
}

/// Probe a site with no identifying key.  Returns `true` iff the armed
/// plan schedules a fault at this probe.
#[inline]
pub fn should_fault(site: Site) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    probe(site, None)
}

/// Probe a site carrying a key (e.g. a job sequence number); sites whose
/// spec sets `key` only fire on matching probes.
#[inline]
pub fn should_fault_keyed(site: Site, key: u64) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    probe(site, Some(key))
}

fn probe(site: Site, key: Option<u64>) -> bool {
    match active() {
        Some(a) => a.probe(site, key),
        None => false,
    }
}

impl Active {
    fn probe(&self, site: Site, key: Option<u64>) -> bool {
        let Some(spec) = self.plan.sites.get(&site).copied() else { return false };
        let i = site.index();
        // Every probe advances the counter — the schedule is positional.
        let n = self.probes[i].fetch_add(1, Ordering::Relaxed);
        if let Some(want) = spec.key {
            if key != Some(want) {
                return false;
            }
        }
        if n < spec.after || (n - spec.after) % spec.period != self.offsets[i] {
            return false;
        }
        // Spend one unit of the fault budget; CAS so racing probes can't
        // overshoot `max`.
        loop {
            let f = self.fired[i].load(Ordering::Relaxed);
            if f >= spec.max {
                return false;
            }
            if self.fired[i]
                .compare_exchange(f, f + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return true;
            }
        }
    }
}

/// RAII handle for a test-scoped armed plan.  Holding it excludes every
/// other `arm_scoped` caller; dropping it disarms.
pub struct ArmGuard {
    active: Arc<Active>,
    _lock: MutexGuard<'static, ()>,
}

impl ArmGuard {
    /// Faults actually delivered at `site` so far.
    pub fn fired(&self, site: Site) -> u64 {
        self.active.fired[site.index()].load(Ordering::Relaxed)
    }

    /// Probes observed at `site` so far (fired or not).
    pub fn probes(&self, site: Site) -> u64 {
        self.active.probes[site.index()].load(Ordering::Relaxed)
    }
}

impl Drop for ArmGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        *ACTIVE.lock().unwrap_or_else(|p| p.into_inner()) = None;
    }
}

/// Arm `plan` for the lifetime of the returned guard.  Blocks until any
/// other armed guard drops; use from tests.
pub fn arm_scoped(plan: FaultPlan) -> ArmGuard {
    let lock = ARM_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let active = Arc::new(Active::new(plan));
    *ACTIVE.lock().unwrap_or_else(|p| p.into_inner()) = Some(active.clone());
    ARMED.store(true, Ordering::SeqCst);
    ArmGuard { active, _lock: lock }
}

/// Arm `plan` for the remainder of the process (the `--fault-plan` CLI
/// path).  Never disarmed.
pub fn arm(plan: FaultPlan) {
    std::mem::forget(arm_scoped(plan));
}

/// Holds the arming mutex WITHOUT arming anything: for tests that probe
/// sites for real (file I/O, checkpoint commits) and must never observe a
/// concurrently armed test's faults.  An `ArmGuard` disarms before its
/// lock is released, so acquiring this guarantees no plan is armed.
pub struct ExclusionGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

pub fn exclude_faults() -> ExclusionGuard {
    ExclusionGuard(ARM_LOCK.lock().unwrap_or_else(|p| p.into_inner()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Which probe indices fault over `n` unkeyed probes of an [`Active`]
    /// instance.  Driving `Active` directly (instead of the armed global)
    /// keeps these tests deterministic while unrelated lib tests do real
    /// I/O on other threads.
    fn positions(a: &Active, site: Site, n: u64) -> Vec<u64> {
        (0..n).filter(|_| a.probe(site, None)).collect()
    }

    #[test]
    fn unarmed_probes_never_fault() {
        for site in ALL_SITES {
            assert!(!should_fault(site));
            assert!(!should_fault_keyed(site, 7));
        }
    }

    #[test]
    fn schedule_is_a_pure_function_of_the_seed() {
        let plan = |seed| {
            FaultPlan::new(seed)
                .site(Site::IoRead, SiteSpec { period: 6, max: 5, ..Default::default() })
        };
        let a = positions(&Active::new(plan(42)), Site::IoRead, 64);
        let b = positions(&Active::new(plan(42)), Site::IoRead, 64);
        assert_eq!(a, b, "same seed must give the same schedule");
        assert_eq!(a.len(), 5);
        let c = positions(&Active::new(plan(43)), Site::IoRead, 64);
        assert_ne!(a, c, "a different seed should shift the phase");
    }

    #[test]
    fn periodic_schedule_never_faults_adjacent_probes() {
        let a = Active::new(
            FaultPlan::new(7).site(Site::IoRead, SiteSpec { period: 3, ..Default::default() }),
        );
        let pos = positions(&a, Site::IoRead, 99);
        assert_eq!(pos.len(), 33);
        for w in pos.windows(2) {
            assert_eq!(w[1] - w[0], 3, "strict period ⇒ a retry always succeeds");
        }
    }

    #[test]
    fn max_budget_and_after_are_respected() {
        let a = Active::new(FaultPlan::new(1).site(
            Site::IoWrite,
            SiteSpec { period: 2, max: 3, after: 10, ..Default::default() },
        ));
        let pos = positions(&a, Site::IoWrite, 200);
        assert_eq!(pos.len(), 3, "max caps total faults");
        assert!(pos.iter().all(|&p| p >= 10), "after delays the schedule");
        assert_eq!(a.fired[Site::IoWrite.index()].load(Ordering::Relaxed), 3);
        assert_eq!(a.probes[Site::IoWrite.index()].load(Ordering::Relaxed), 200);
    }

    #[test]
    fn key_filter_targets_one_probe_stream() {
        let a = Active::new(
            FaultPlan::new(5)
                .site(Site::WorkerPanic, SiteSpec { key: Some(9), ..Default::default() }),
        );
        assert!(!a.probe(Site::WorkerPanic, Some(8)));
        assert!(!a.probe(Site::WorkerPanic, None));
        assert!(a.probe(Site::WorkerPanic, Some(9)));
    }

    #[test]
    fn arm_scoped_arms_and_disarms_the_global_registry() {
        // Key-filtered with an unguessable key: concurrently running lib
        // tests that probe sites for real can neither fire this plan nor
        // be fired at, regardless of interleaving (period 1 makes every
        // matching probe eligible, so counter position is irrelevant).
        const KEY: u64 = 0xDEAD_BEEF_F417_0001;
        {
            let g = arm_scoped(FaultPlan::new(3).site(
                Site::WorkerPanic,
                SiteSpec { key: Some(KEY), ..Default::default() },
            ));
            assert!(!should_fault_keyed(Site::WorkerPanic, KEY ^ 1));
            assert!(should_fault_keyed(Site::WorkerPanic, KEY));
            assert!(g.fired(Site::WorkerPanic) >= 1);
        }
        assert!(!should_fault_keyed(Site::WorkerPanic, KEY), "drop must disarm");
    }

    #[test]
    fn plan_parsing_round_trips_the_flag_syntax() {
        let p =
            FaultPlan::parse("seed=42; io_read:period=6,max=3 ;worker_panic:max=2,key=7").unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(
            p.spec(Site::IoRead),
            Some(&SiteSpec { period: 6, max: 3, after: 0, key: None })
        );
        assert_eq!(
            p.spec(Site::WorkerPanic),
            Some(&SiteSpec { period: 1, max: 2, after: 0, key: Some(7) })
        );
        assert!(p.spec(Site::ConnStall).is_none());
        assert!(FaultPlan::parse("seed=1").is_err(), "no sites is an error");
        assert!(FaultPlan::parse("io_reed").is_err(), "unknown site is an error");
        assert!(FaultPlan::parse("io_read:period=0").is_err(), "period 0 is an error");
        assert!(FaultPlan::parse("io_read:frequency=2").is_err(), "unknown param");
    }

    #[test]
    fn transient_marker_classification() {
        assert!(is_transient("read failed (transient): os error 4"));
        assert!(!is_transient("bad magic"));
    }
}
