//! Declarative command-line parsing (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! typed accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// A declarative command description: name, help text, and options.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Declares `--name <value>` with an optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default,
            is_switch: false,
        });
        self
    }

    /// Declares a boolean `--name` switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_switch: true,
        });
        self
    }

    fn find(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Renders usage/help text.
    pub fn usage(&self, prog: &str) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "USAGE: {prog} {} [OPTIONS]\n\nOPTIONS:", self.name);
        for o in &self.opts {
            let lhs = if o.is_switch {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let dft = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  {lhs:<24} {}{dft}", o.help);
        }
        s
    }

    /// Parses `args` (not including program/subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .find(name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                let val = if spec.is_switch {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    "true".to_string()
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next()
                        .cloned()
                        .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                };
                values.insert(name.to_string(), val);
            } else {
                positional.push(arg.clone());
            }
        }
        // Fill defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(Matches { values, positional })
    }
}

/// Parse failure (message already formatted for display).
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed option values with typed accessors.
#[derive(Debug, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected a number")))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }

    pub fn req(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing required option --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("decompose", "run the pipeline")
            .opt("size", "tensor side", Some("400"))
            .opt("rank", "CP rank", Some("5"))
            .opt("out", "output path", None)
            .switch("verbose", "log more")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&args(&[])).unwrap();
        assert_eq!(m.get_usize("size").unwrap(), 400);
        assert_eq!(m.get("out"), None);
        assert!(!m.get_bool("verbose"));
    }

    #[test]
    fn explicit_values_win() {
        let m = cmd()
            .parse(&args(&["--size", "100", "--rank=8", "--verbose"]))
            .unwrap();
        assert_eq!(m.get_usize("size").unwrap(), 100);
        assert_eq!(m.get_usize("rank").unwrap(), 8);
        assert!(m.get_bool("verbose"));
    }

    #[test]
    fn positional_collected() {
        let m = cmd().parse(&args(&["file.bin", "--size", "10"])).unwrap();
        assert_eq!(m.positional, vec!["file.bin"]);
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&args(&["--bogus", "1"])).is_err());
        assert!(cmd().parse(&args(&["--size"])).is_err());
        assert!(cmd().parse(&args(&["--verbose=yes"])).is_err());
        let m = cmd().parse(&args(&["--size", "abc"])).unwrap();
        assert!(m.get_usize("size").is_err());
    }

    #[test]
    fn usage_mentions_all_opts() {
        let u = cmd().usage("exatensor");
        for name in ["--size", "--rank", "--out", "--verbose"] {
            assert!(u.contains(name), "missing {name} in usage");
        }
    }
}
