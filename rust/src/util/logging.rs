//! Tiny `log` facade backend: timestamped stderr logger with a level filter
//! from `EXATENSOR_LOG` (error|warn|info|debug|trace; default info).

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Installs the logger (idempotent) and applies `EXATENSOR_LOG`.
pub fn init() {
    let level = match std::env::var("EXATENSOR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    START.get_or_init(Instant::now);
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
