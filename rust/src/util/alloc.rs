//! Counting global allocator — the perf-trajectory benches' substitute for
//! heap profilers (offline environment: no `dhat`/`jemalloc` stats).
//!
//! [`CountingAlloc`] wraps [`System`] and tracks three relaxed atomic
//! gauges: cumulative bytes ever allocated, currently-live bytes, and the
//! live high-water mark.  A bench binary installs it with
//! `#[global_allocator]` and brackets a closure to attribute bytes to one
//! kernel call — this is how `BENCH_gemm_mttkrp.json` proves the fused
//! MTTKRP never allocates its `(J·K)×R` Khatri-Rao intermediate.
//!
//! Counters are process-global, so measurements are only meaningful while
//! the bracketed region runs single-threaded (pool scopes inside the
//! region still count — their allocations are genuinely part of the call's
//! cost).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Byte-counting wrapper around the system allocator.
pub struct CountingAlloc {
    /// Cumulative bytes ever handed out (never decreases).
    allocated: AtomicUsize,
    /// Bytes currently live.
    live: AtomicUsize,
    /// High-water mark of `live` since the last [`CountingAlloc::reset_peak`].
    peak: AtomicUsize,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        Self {
            allocated: AtomicUsize::new(0),
            live: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// Cumulative bytes ever allocated.
    pub fn allocated_bytes(&self) -> usize {
        self.allocated.load(Ordering::Relaxed)
    }

    /// Bytes currently live.
    pub fn live_bytes(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// High-water mark of live bytes since the last reset.
    pub fn peak_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Resets the high-water mark to the current live size, so the next
    /// reading isolates one region's transient footprint.
    pub fn reset_peak(&self) {
        self.peak.store(self.live.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    fn on_alloc(&self, size: usize) {
        self.allocated.fetch_add(size, Ordering::Relaxed);
        let live = self.live.fetch_add(size, Ordering::Relaxed) + size;
        self.peak.fetch_max(live, Ordering::Relaxed);
    }

    fn on_dealloc(&self, size: usize) {
        self.live.fetch_sub(size, Ordering::Relaxed);
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: pure delegation to `System`; the counters never influence the
// returned pointers or layouts.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            self.on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        self.on_dealloc(layout.size());
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            self.on_alloc(layout.size());
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // Count the grown block as a fresh allocation and retire the
            // old size: cumulative counts every byte ever requested, live
            // nets out to the delta.
            self.on_alloc(new_size);
            self.on_dealloc(layout.size());
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Not installed as the test harness's global allocator (that would
    // perturb every other test); exercised through the counter methods.
    #[test]
    fn counters_track_alloc_dealloc() {
        let a = CountingAlloc::new();
        a.on_alloc(1000);
        a.on_alloc(500);
        assert_eq!(a.allocated_bytes(), 1500);
        assert_eq!(a.live_bytes(), 1500);
        assert_eq!(a.peak_bytes(), 1500);
        a.on_dealloc(1000);
        assert_eq!(a.live_bytes(), 500);
        assert_eq!(a.peak_bytes(), 1500, "peak survives frees");
        a.reset_peak();
        assert_eq!(a.peak_bytes(), 500);
        a.on_alloc(100);
        assert_eq!(a.peak_bytes(), 600);
        assert_eq!(a.allocated_bytes(), 1600, "cumulative never decreases");
    }
}
