//! Stable content hashing (FNV-1a).
//!
//! Hoisted from `serve/cache.rs` once checkpoint integrity needed the same
//! digest discipline: the serve-layer cache keys, the protocol's
//! `model_digest` bitwise-identity witness, and the checkpoint payload
//! digests must all agree on one tiny, dependency-free, cross-platform
//! hash.  FNV-1a is not cryptographic — it detects bit rot and torn
//! writes, not adversaries.

/// 64-bit FNV-1a of `bytes`, one-shot.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write(bytes);
    h.finish()
}

/// Incremental FNV-1a hasher.
pub struct Fnv {
    state: u64,
}

impl Fnv {
    pub fn new() -> Self {
        Self { state: 0xcbf2_9ce4_8422_2325 }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}
