//! Standard base64 (RFC 4648, with `=` padding), hand-rolled because the
//! build environment is offline (see `util/mod.rs`).  Used for the
//! sharded plane's `PARTIAL` payloads: base64 costs 4 bytes per 3 input
//! bytes where the old hex codec cost 2 per 1 — a 1.5× wire-byte saving
//! on every shard accumulator crossing the serve protocol.

use anyhow::{bail, Result};

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encodes `data` as standard padded base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    let mut chunks = data.chunks_exact(3);
    for c in &mut chunks {
        let v = ((c[0] as u32) << 16) | ((c[1] as u32) << 8) | c[2] as u32;
        out.push(ALPHABET[(v >> 18) as usize & 63] as char);
        out.push(ALPHABET[(v >> 12) as usize & 63] as char);
        out.push(ALPHABET[(v >> 6) as usize & 63] as char);
        out.push(ALPHABET[v as usize & 63] as char);
    }
    match chunks.remainder() {
        [] => {}
        [a] => {
            let v = (*a as u32) << 16;
            out.push(ALPHABET[(v >> 18) as usize & 63] as char);
            out.push(ALPHABET[(v >> 12) as usize & 63] as char);
            out.push('=');
            out.push('=');
        }
        [a, b] => {
            let v = ((*a as u32) << 16) | ((*b as u32) << 8);
            out.push(ALPHABET[(v >> 18) as usize & 63] as char);
            out.push(ALPHABET[(v >> 12) as usize & 63] as char);
            out.push(ALPHABET[(v >> 6) as usize & 63] as char);
            out.push('=');
        }
        _ => unreachable!("chunks_exact(3) remainder is < 3"),
    }
    out
}

fn sextet(c: u8) -> Result<u32> {
    Ok(match c {
        b'A'..=b'Z' => (c - b'A') as u32,
        b'a'..=b'z' => (c - b'a' + 26) as u32,
        b'0'..=b'9' => (c - b'0' + 52) as u32,
        b'+' => 62,
        b'/' => 63,
        _ => bail!("invalid base64 byte {c:#04x}"),
    })
}

/// Inverse of [`encode`].  Rejects unpadded, mis-padded, and non-alphabet
/// input loudly — a truncated wire payload must fail, not silently decode
/// to a short accumulator.
pub fn decode(s: &str) -> Result<Vec<u8>> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        bail!("base64 length {} is not a multiple of 4", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (i, q) in bytes.chunks_exact(4).enumerate() {
        let last = (i + 1) * 4 == bytes.len();
        let pad = q.iter().filter(|&&c| c == b'=').count();
        if pad > 2 || (!last && pad > 0) || (pad >= 1 && q[3] != b'=') || (pad == 2 && q[2] != b'=')
        {
            bail!("malformed base64 padding");
        }
        let v = (sextet(q[0])? << 18)
            | (sextet(q[1])? << 12)
            | (if pad == 2 { 0 } else { sextet(q[2])? << 6 })
            | (if pad >= 1 { 0 } else { sextet(q[3])? });
        out.push((v >> 16) as u8);
        if pad < 2 {
            out.push((v >> 8) as u8);
        }
        if pad < 1 {
            out.push(v as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        // The canonical test vectors from RFC 4648 §10.
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn round_trips_all_byte_values() {
        let data: Vec<u8> = (0..=255u8).collect();
        for len in [0, 1, 2, 3, 4, 100, 255, 256] {
            let slice = &data[..len.min(data.len())];
            assert_eq!(decode(&encode(slice)).unwrap(), slice, "len {len}");
        }
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(decode("Zg=").is_err(), "length not a multiple of 4");
        assert!(decode("Z===").is_err(), "three pad chars");
        assert!(decode("Zg==Zm8=").is_err(), "padding mid-stream");
        assert!(decode("Zm 9").is_err(), "non-alphabet byte");
        assert!(decode("=m9v").is_err(), "pad in the wrong slot");
    }
}
