//! Hand-rolled utility substrates.
//!
//! This build environment is offline: only the `xla` crate's dependency
//! closure is present in the registry cache, so the usual ecosystem crates
//! (clap, serde, rand, rayon, criterion, proptest, half) are unavailable.
//! Each submodule here replaces one of them with a small, tested
//! implementation — see DESIGN.md "Offline-crate substitutions".

pub mod alloc;
pub mod b64;
pub mod cli;
pub mod f16;
pub mod fault;
pub mod hash;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Returns the number of worker threads to use by default: the parallelism
/// reported by the OS, capped so test machines don't oversubscribe.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}
