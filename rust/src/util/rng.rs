//! Pseudo-random number generation (offline substitute for the `rand` crate).
//!
//! Implements SplitMix64 (seed expansion), xoshiro256++ (the main generator),
//! a Box-Muller normal sampler with caching, Fisher-Yates shuffling, and the
//! sparse ±1 Rademacher sampler used by the compressed-sensing maps of
//! §IV-D.  All generators are deterministic given a seed so every experiment
//! in EXPERIMENTS.md is reproducible.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
/// Reference: Steele, Lea & Flood, "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// SplitMix64's avalanche finalizer as a standalone bijective mixer — the
/// primitive behind the **counter-based** generator below.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Absorbs one key word into a running hash: multiply by an odd constant
/// (so position matters — `absorb(absorb(h,a),b) ≠ absorb(absorb(h,b),a)`)
/// then a full avalanche.  Philox/Squares-style keyed counter hashing,
/// built from the SplitMix64 finalizer we already carry.
#[inline]
fn absorb(h: u64, v: u64) -> u64 {
    mix64(h.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ v)
}

/// Collapses a structured 5-word key into one mixed 64-bit word.  This is
/// the **random-access** generator used by the procedural replica-map tier:
/// any `(seed, replica, mode, row, col)` coordinate maps to its value with
/// no sequential state, so panels can be synthesized in any order, on any
/// thread, and always come out identical.
#[inline]
pub fn counter_key(seed: u64, a: u64, b: u64, c: u64, d: u64) -> u64 {
    // wyhash's prime as the starting constant; five absorb rounds give
    // full avalanche between every word and the output.
    absorb(absorb(absorb(absorb(absorb(0xA076_1D64_78BD_642F, seed), a), b), c), d)
}

/// Standard-normal `f32` from a single counter key.
///
/// Uses the **trigonometric** Box-Muller form (not the polar/rejection form
/// of [`Xoshiro256::next_gaussian`]): every key maps to exactly one value
/// with no retry loop, which is what makes the mapping a pure function of
/// the key — the property the generate-on-slice map tier depends on.
/// `u1` is biased into `(0, 1]` so `ln` never sees zero.
#[inline]
pub fn gaussian_from_key(key: u64) -> f32 {
    let a = mix64(key ^ 0xD1B5_4A32_D192_ED03);
    let b = mix64(key ^ 0x8EBC_6AF0_9C88_C6E3);
    let u1 = ((a >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64);
    let u2 = (b >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let r = (-2.0 * u1.ln()).sqrt();
    (r * (std::f64::consts::TAU * u2).cos()) as f32
}

/// xoshiro256++ 1.0 — fast, high-quality 64-bit generator
/// (Blackman & Vigna, 2019).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// cached second value from Box-Muller
    gauss_cache: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

impl Xoshiro256 {
    /// Creates a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self {
            s,
            gauss_cache: None,
        }
    }

    /// Derives an independent stream for worker `index` — used to give each
    /// replica / worker thread its own deterministic stream.
    pub fn stream(&self, index: u64) -> Self {
        // Re-seed through SplitMix64 with a mixed-in stream index; streams
        // are disjoint with overwhelming probability for distinct indices.
        let mix = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ index.wrapping_mul(0xA24B_AED4_963E_E407);
        Self::seed_from_u64(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (polar form), cached pairs.
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.gauss_cache = Some(v * k);
                return u * k;
            }
        }
    }

    /// Fills a slice with i.i.d. standard-normal `f32` values.
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.next_gaussian() as f32;
        }
    }

    /// Vector of i.i.d. standard-normal `f32` values.
    pub fn gaussian_vec_f32(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_gaussian_f32(&mut v);
        v
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Samples `k` distinct indices from `[0, n)` (Floyd's algorithm),
    /// returned sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.next_below(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Rademacher ±1 sample.
    #[inline]
    pub fn next_sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (cross-checked against the reference C).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let base = Xoshiro256::seed_from_u64(7);
        let mut s0 = base.stream(0);
        let mut s1 = base.stream(1);
        let same = (0..64).filter(|_| s0.next_u64() == s1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.next_gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = rng.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let idx = rng.sample_indices(50, 12);
        assert_eq!(idx.len(), 12);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*idx.last().unwrap() < 50);
    }

    #[test]
    fn counter_key_is_pure_and_order_sensitive() {
        // Pure function: same coordinates → same word.
        assert_eq!(counter_key(7, 1, 2, 3, 4), counter_key(7, 1, 2, 3, 4));
        // Every word position matters (transposed coordinates differ).
        assert_ne!(counter_key(7, 1, 2, 3, 4), counter_key(7, 2, 1, 3, 4));
        assert_ne!(counter_key(7, 1, 2, 3, 4), counter_key(7, 1, 2, 4, 3));
        assert_ne!(counter_key(7, 1, 2, 3, 4), counter_key(8, 1, 2, 3, 4));
        // No trivial collisions over a coordinate grid.
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..20u64 {
            for b in 0..20u64 {
                for c in 0..20u64 {
                    assert!(seen.insert(counter_key(9, a, b, c, 0)));
                }
            }
        }
    }

    #[test]
    fn counter_gaussian_moments() {
        // The keyed sampler must match the sequential sampler's
        // distribution: mean 0, variance 1, bounded tails.
        let n = 50_000u64;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for i in 0..n {
            let g = gaussian_from_key(counter_key(42, i, 0, 0, 0)) as f64;
            assert!(g.is_finite());
            assert!(g.abs() < 10.0, "implausible tail {g}");
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn counter_gaussian_decorrelated_across_key_words() {
        // Adjacent coordinates (the worst case for a weak mixer) must be
        // uncorrelated.
        let n = 20_000u64;
        let mut dot = 0.0f64;
        for i in 0..n {
            let x = gaussian_from_key(counter_key(3, i, 0, 5, 9)) as f64;
            let y = gaussian_from_key(counter_key(3, i + 1, 0, 5, 9)) as f64;
            dot += x * y;
        }
        assert!((dot / n as f64).abs() < 0.03, "lag-1 corr {}", dot / n as f64);
    }

    #[test]
    fn signs_are_balanced() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let pos = (0..10_000).filter(|_| rng.next_sign() > 0.0).count();
        assert!((4_700..5_300).contains(&pos), "pos={pos}");
    }
}
