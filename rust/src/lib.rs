//! # Exascale-Tensor
//!
//! Reproduction of *"Scalable CP Decomposition for Tensor Learning using GPU
//! Tensor Cores"* (Zhang et al., 2023): a compression-based CP decomposition
//! framework that trades computation for storage so that tensors far larger
//! than main memory can be decomposed.
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (blocked TTM compression, MTTKRP, split-precision
//!   matmul) authored in `python/compile/kernels/`, lowered ahead of time.
//! * **L2** — JAX graphs (`python/compile/model.py`) calling the kernels,
//!   exported once as HLO text into `artifacts/`.
//! * **L3** — this crate: block streaming, the proxy-tensor pipeline of
//!   Alg. 2 (compress → decompose → match → recover), memory planning,
//!   worker pools, and the PJRT runtime that executes the artifacts.
//!
//! Python never runs on the request path; after `make artifacts` the
//! `exatensor` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use exascale_tensor::coordinator::{Pipeline, PipelineConfig};
//! use exascale_tensor::tensor::generator::LowRankGenerator;
//!
//! let gen = LowRankGenerator::new(400, 400, 400, 5, 42);
//! let cfg = PipelineConfig::builder()
//!     .reduced_dims(50, 50, 50)
//!     .rank(5)
//!     .build()
//!     .unwrap();
//! let mut pipe = Pipeline::new(cfg);
//! let result = pipe.run(&gen).unwrap();
//! println!("relative factor error: {}", result.diagnostics.max_factor_error);
//! ```

pub mod apps;
pub mod bench_harness;
pub mod compress;
pub mod coordinator;
pub mod cp;
pub mod linalg;
pub mod mixed;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod util;

pub use coordinator::{Pipeline, PipelineConfig, PipelineResult};
pub use tensor::{DenseTensor, SparseTensor};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
