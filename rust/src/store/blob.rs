//! On-disk blob format + atomic publish for the artifact store.
//!
//! A blob is self-describing:
//!
//! ```text
//! EXBLOB1\n
//! {"class":"proxies","key":"<16hex>","digest":"<16hex>","tensors":N,"meta":{…}}\n
//! <payload: per tensor, 3 × u64-LE dims then l·m·n × f32-LE>
//! ```
//!
//! `digest` is FNV-1a over the payload bytes exactly as written, so a
//! torn write, a flipped bit, or a foreign file under the right name is
//! detected on read — the store quarantines such blobs and reports a
//! miss, and the pipeline recomputes (the bitwise-reuse contract would
//! otherwise be silently broken).
//!
//! Publish is write-to-temp + `rename` onto the final path: readers only
//! ever observe complete blobs, and two publishers racing on one key
//! both succeed — the last rename wins and the loser's identical bytes
//! are simply replaced.

use super::key::StageKey;
use crate::tensor::DenseTensor;
use crate::util::hash::Fnv;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &str = "EXBLOB1";

fn payload_bytes(tensors: &[DenseTensor]) -> Vec<u8> {
    let total: usize = tensors.iter().map(|t| 24 + t.data().len() * 4).sum();
    let mut out = Vec::with_capacity(total);
    for t in tensors {
        for d in t.dims() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

fn digest(payload: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write(payload);
    h.finish()
}

/// Serializes `tensors` (+ free-form `meta`) into `tmp`, then atomically
/// renames it onto `path`.  Returns the published byte size.
pub fn publish_blob(
    tmp: &Path,
    path: &Path,
    key: &StageKey,
    tensors: &[DenseTensor],
    meta: &Json,
) -> Result<u64> {
    let payload = payload_bytes(tensors);
    let header = Json::obj(vec![
        ("class", Json::str(key.class.dir_name())),
        ("key", Json::str(key.hash.clone())),
        ("digest", Json::str(format!("{:016x}", digest(&payload)))),
        ("tensors", Json::num(tensors.len() as f64)),
        ("meta", meta.clone()),
    ]);
    let mut bytes = 0u64;
    {
        let f = std::fs::File::create(tmp)
            .with_context(|| format!("creating blob temp {}", tmp.display()))?;
        let mut w = BufWriter::new(f);
        let head = format!("{MAGIC}\n{}\n", header.to_string_compact());
        w.write_all(head.as_bytes()).context("writing blob header")?;
        w.write_all(&payload).context("writing blob payload")?;
        bytes += head.len() as u64 + payload.len() as u64;
        w.flush().context("flushing blob")?;
    }
    std::fs::rename(tmp, path)
        .with_context(|| format!("publishing blob {}", path.display()))?;
    Ok(bytes)
}

/// Reads and fully verifies a blob: magic, class, key, and payload
/// digest.  Any mismatch is an error — the caller treats it as
/// corruption, quarantines the file, and recomputes.
pub fn read_blob(path: &Path, key: &StageKey) -> Result<(Vec<DenseTensor>, Json)> {
    let mut raw = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut raw))
        .with_context(|| format!("reading blob {}", path.display()))?;
    let magic_end = MAGIC.len();
    if raw.len() < magic_end + 1 || &raw[..magic_end] != MAGIC.as_bytes() || raw[magic_end] != b'\n'
    {
        bail!("blob {}: bad magic", path.display());
    }
    let header_end = raw[magic_end + 1..]
        .iter()
        .position(|&b| b == b'\n')
        .map(|p| magic_end + 1 + p)
        .with_context(|| format!("blob {}: truncated header", path.display()))?;
    let header_text = std::str::from_utf8(&raw[magic_end + 1..header_end])
        .with_context(|| format!("blob {}: non-UTF8 header", path.display()))?;
    let header = Json::parse(header_text)
        .with_context(|| format!("blob {}: unparseable header", path.display()))?;
    let claim = |k: &str| -> Result<String> {
        Ok(header
            .get(k)
            .and_then(|x| x.as_str())
            .with_context(|| format!("blob header missing {k}"))?
            .to_string())
    };
    if claim("class")? != key.class.dir_name() || claim("key")? != key.hash {
        bail!("blob {}: addressed as {} but claims another key", path.display(), key.id());
    }
    let want = u64::from_str_radix(&claim("digest")?, 16).context("blob header digest")?;
    let payload = &raw[header_end + 1..];
    if digest(payload) != want {
        bail!("blob {}: payload digest mismatch", path.display());
    }
    let count = header
        .get("tensors")
        .and_then(|x| x.as_usize())
        .context("blob header missing tensors")?;
    let meta = header.get("meta").cloned().unwrap_or(Json::Null);
    let mut tensors = Vec::with_capacity(count);
    let mut off = 0usize;
    for _ in 0..count {
        if payload.len() < off + 24 {
            bail!("blob {}: truncated tensor dims", path.display());
        }
        let mut dims = [0usize; 3];
        for d in dims.iter_mut() {
            let mut le = [0u8; 8];
            le.copy_from_slice(&payload[off..off + 8]);
            *d = u64::from_le_bytes(le) as usize;
            off += 8;
        }
        let n = dims[0]
            .checked_mul(dims[1])
            .and_then(|x| x.checked_mul(dims[2]))
            .context("blob tensor dims overflow")?;
        if payload.len() < off + n * 4 {
            bail!("blob {}: truncated tensor payload", path.display());
        }
        let mut data = Vec::with_capacity(n);
        for ch in payload[off..off + n * 4].chunks_exact(4) {
            data.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
        }
        off += n * 4;
        tensors.push(DenseTensor::from_vec(dims, data));
    }
    if off != payload.len() {
        bail!("blob {}: {} trailing payload bytes", path.display(), payload.len() - off);
    }
    Ok((tensors, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_blob_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn key() -> StageKey {
        StageKey::proxies(1, [4, 4, 4], [2, 2, 2], 2, 2, 0, false, [2, 2, 2], "batched")
    }

    fn tensors() -> Vec<DenseTensor> {
        vec![
            DenseTensor::from_vec([2, 2, 2], vec![1.0, -0.0, 2.5, -3.0, 1e-30, 4.0, 5.0, 6.0]),
            DenseTensor::from_vec([1, 2, 3], vec![0.5; 6]),
        ]
    }

    #[test]
    fn round_trips_bitwise_with_meta() {
        let dir = tmpdir("roundtrip");
        let k = key();
        let meta = Json::obj(vec![("rel_error", Json::num(0.25))]);
        let path = dir.join("x.blob");
        publish_blob(&dir.join("x.tmp"), &path, &k, &tensors(), &meta).unwrap();
        let (back, m) = read_blob(&path, &k).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in tensors().iter().zip(&back) {
            assert_eq!(a.dims(), b.dims());
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "payload must round-trip bitwise");
            }
        }
        assert_eq!(m.get("rel_error").and_then(|x| x.as_f64()), Some(0.25));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_and_wrong_key_are_loud() {
        let dir = tmpdir("corrupt");
        let k = key();
        let path = dir.join("x.blob");
        publish_blob(&dir.join("x.tmp"), &path, &k, &tensors(), &Json::Null).unwrap();
        // A flipped payload byte fails the digest.
        let mut raw = std::fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0x40;
        std::fs::write(&path, &raw).unwrap();
        assert!(read_blob(&path, &k).is_err(), "bit flip must be detected");
        // Reading under the wrong key fails even with intact bytes.
        publish_blob(&dir.join("x.tmp"), &path, &k, &tensors(), &Json::Null).unwrap();
        let other = StageKey::shard_accum(&k, 0, 0);
        assert!(read_blob(&path, &other).is_err(), "key mismatch must be detected");
        // Truncation fails.
        let raw = std::fs::read(&path).unwrap();
        std::fs::write(&path, &raw[..raw.len() - 2]).unwrap();
        assert!(read_blob(&path, &k).is_err(), "truncation must be detected");
        std::fs::remove_dir_all(&dir).ok();
    }
}
