//! Content-addressed artifact store with stage-level reuse (salsa-style).
//!
//! The serve plane's LRU result cache only hits on exact whole-job
//! fingerprints, so every rank-sweep resubmit re-runs Stage 1 compression
//! — the dominant cost of the whole pipeline (streaming a multi-TB source
//! through the engine).  This store keeps **stage-level** artifacts under
//! typed keys `(input digest, stage-config subset)` (see [`key`]) so that
//! work whose inputs have not changed is fetched, not recomputed:
//!
//! * **Compressed proxy sets** — a rank sweep over one tensor streams the
//!   source once; ranks 2..N reuse the first job's proxies bit-for-bit.
//! * **Raw shard accumulators** — the sharded plane's verified `PARTIAL`
//!   payloads; a restarted or re-submitted sharded job refetches finished
//!   shards instead of re-leasing them.
//! * **Final factor sets** — the old whole-job result cache, now a thin
//!   view over the store ([`crate::serve::cache::ResultCache`]).
//!
//! Mechanics: one blob file per artifact under
//! `<root>/{proxies,shards,factors}/<16hex>.blob`, published by
//! write-to-temp + atomic rename ([`blob`]), verified by an FNV payload
//! digest on every read.  GC is LRU under a global byte budget; pinned
//! (in-use) artifacts are never evicted; a blob that fails verification
//! is moved to `<root>/quarantine/` and reported as a miss so the caller
//! recomputes — **reuse is only ever bitwise identical or absent**.
//!
//! Observability (daemon metrics): `store_hits_compress`,
//! `store_hits_shards`, `store_hits_factors`, `store_publishes`,
//! `store_evictions`, `store_corrupt` counters and the `store_bytes` /
//! `store_entries` gauges.

pub mod blob;
pub mod key;

pub use key::{ArtifactClass, StageKey};

use crate::coordinator::Metrics;
use crate::tensor::DenseTensor;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone per-class counters (the factor class feeds the legacy
/// `cache_*` gauges).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub used_bytes: usize,
    pub entries: usize,
}

struct Entry {
    bytes: usize,
    last_used: u64,
    pins: usize,
}

#[derive(Default)]
struct PerClass {
    hits: u64,
    misses: u64,
    evictions: u64,
}

struct State {
    /// Keyed by [`StageKey::id`] (`class/hash`).
    entries: HashMap<String, Entry>,
    used: usize,
    tick: u64,
    classes: [PerClass; 3],
}

/// Byte-budgeted, content-addressed blob store.  All methods are `&self`;
/// share it behind an `Arc` (pinning requires the `Arc`).
pub struct ArtifactStore {
    root: PathBuf,
    budget: usize,
    metrics: Arc<Metrics>,
    state: Mutex<State>,
    tmp_seq: AtomicU64,
}

fn class_ix(c: ArtifactClass) -> usize {
    match c {
        ArtifactClass::Proxies => 0,
        ArtifactClass::ShardAccum => 1,
        ArtifactClass::Factors => 2,
    }
}

fn hit_counter(c: ArtifactClass) -> &'static str {
    match c {
        ArtifactClass::Proxies => "store_hits_compress",
        ArtifactClass::ShardAccum => "store_hits_shards",
        ArtifactClass::Factors => "store_hits_factors",
    }
}

impl ArtifactStore {
    /// Opens (and if needed creates) a store rooted at `root`, rebuilding
    /// the index from the blobs already on disk.  Leftover temp files
    /// from a killed publisher are swept.  `budget` = 0 disables the
    /// store entirely: every get misses and publishes are dropped.
    pub fn open(root: impl Into<PathBuf>, budget: usize, metrics: Arc<Metrics>) -> Result<Self> {
        let root = root.into();
        for sub in ["proxies", "shards", "factors", "tmp", "quarantine"] {
            std::fs::create_dir_all(root.join(sub))
                .with_context(|| format!("creating store {}/{sub}", root.display()))?;
        }
        let mut state = State {
            entries: HashMap::new(),
            used: 0,
            tick: 0,
            classes: Default::default(),
        };
        for class in ["proxies", "shards", "factors"] {
            let mut files: Vec<(String, usize)> = Vec::new();
            for e in std::fs::read_dir(root.join(class))?.flatten() {
                let path = e.path();
                if path.extension().and_then(|x| x.to_str()) != Some("blob") {
                    continue;
                }
                let (Some(stem), Ok(meta)) =
                    (path.file_stem().and_then(|x| x.to_str()), e.metadata())
                else {
                    continue;
                };
                files.push((format!("{class}/{stem}"), meta.len() as usize));
            }
            // Deterministic recovery order: the rebuilt LRU ranks blobs by
            // id, oldest-rank-first, since mtimes are not trustworthy.
            files.sort();
            for (id, bytes) in files {
                state.tick += 1;
                state.used += bytes;
                state.entries.insert(
                    id,
                    Entry { bytes, last_used: state.tick, pins: 0 },
                );
            }
        }
        for e in std::fs::read_dir(root.join("tmp"))?.flatten() {
            std::fs::remove_file(e.path()).ok();
        }
        let store = Self {
            root,
            budget,
            metrics,
            state: Mutex::new(state),
            tmp_seq: AtomicU64::new(1),
        };
        {
            let mut st = store.state.lock().unwrap();
            store.evict_to_fit(&mut st);
            store.sync_gauges(&st);
        }
        Ok(store)
    }

    fn blob_path(&self, key: &StageKey) -> PathBuf {
        self.root
            .join(key.class.dir_name())
            .join(format!("{}.blob", key.hash))
    }

    /// Whether `key` is resident — does not touch LRU order or counters,
    /// so admission probes don't distort hit metrics.
    pub fn contains(&self, key: &StageKey) -> bool {
        self.state.lock().unwrap().entries.contains_key(&key.id())
    }

    /// Fetches and verifies an artifact.  A digest/format failure
    /// quarantines the blob and reports a miss — the caller recomputes.
    pub fn get(&self, key: &StageKey) -> Option<Vec<DenseTensor>> {
        self.get_with_meta(key).map(|(t, _)| t)
    }

    pub fn get_with_meta(&self, key: &StageKey) -> Option<(Vec<DenseTensor>, Json)> {
        let id = key.id();
        let mut st = self.state.lock().unwrap();
        if !st.entries.contains_key(&id) {
            st.classes[class_ix(key.class)].misses += 1;
            return None;
        }
        match blob::read_blob(&self.blob_path(key), key) {
            Ok(out) => {
                st.tick += 1;
                let tick = st.tick;
                st.entries.get_mut(&id).unwrap().last_used = tick;
                st.classes[class_ix(key.class)].hits += 1;
                self.metrics.incr(hit_counter(key.class), 1);
                Some(out)
            }
            Err(e) => {
                log::warn!("store: quarantining {id}: {e:#}");
                self.quarantine(&mut st, key);
                st.classes[class_ix(key.class)].misses += 1;
                self.metrics.incr("store_corrupt", 1);
                self.sync_gauges(&st);
                None
            }
        }
    }

    /// Moves a failed blob out of the addressable tree so the next run
    /// recomputes (and the bad bytes stay available for a post-mortem).
    fn quarantine(&self, st: &mut State, key: &StageKey) {
        if let Some(e) = st.entries.remove(&key.id()) {
            st.used -= e.bytes;
        }
        let path = self.blob_path(key);
        let n = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let dest = self
            .root
            .join("quarantine")
            .join(format!("{}_{}_{n}.blob", key.class.dir_name(), key.hash));
        if std::fs::rename(&path, &dest).is_err() {
            std::fs::remove_file(&path).ok();
        }
    }

    /// Publishes an artifact: serialize to a unique temp file, atomically
    /// rename onto the content address, index, then evict LRU unpinned
    /// entries until the budget holds again.  A key already resident is
    /// only touched (same key ⇒ same bytes — content addressing makes the
    /// write redundant).  Returns whether a blob was actually written.
    pub fn publish(&self, key: &StageKey, tensors: &[DenseTensor], meta: &Json) -> Result<bool> {
        if self.budget == 0 {
            return Ok(false);
        }
        let id = key.id();
        {
            let mut st = self.state.lock().unwrap();
            if st.entries.contains_key(&id) {
                st.tick += 1;
                let tick = st.tick;
                st.entries.get_mut(&id).unwrap().last_used = tick;
                return Ok(false);
            }
        }
        let n = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .root
            .join("tmp")
            .join(format!("{}.{}.{n}.tmp", key.hash, std::process::id()));
        let bytes = blob::publish_blob(&tmp, &self.blob_path(key), key, tensors, meta)? as usize;
        if bytes > self.budget {
            // Oversized for the whole store: published bytes would evict
            // everything and still not fit.  Withdraw it.
            std::fs::remove_file(self.blob_path(key)).ok();
            log::debug!("store: {id} costs {bytes} B > budget {} B, not stored", self.budget);
            return Ok(false);
        }
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        // A racing publisher may have indexed the same content first; the
        // renames targeted one path, so count the bytes once.
        if let Some(e) = st.entries.get_mut(&id) {
            e.last_used = tick;
        } else {
            st.used += bytes;
            st.entries.insert(id, Entry { bytes, last_used: tick, pins: 0 });
        }
        self.metrics.incr("store_publishes", 1);
        self.evict_to_fit(&mut st);
        self.sync_gauges(&st);
        Ok(true)
    }

    /// Pins an artifact against eviction for the guard's lifetime (e.g.
    /// while an admitted job's warm pricing depends on it staying
    /// resident).  `None` if the key is not resident.
    pub fn pin(self: &Arc<Self>, key: &StageKey) -> Option<PinGuard> {
        let mut st = self.state.lock().unwrap();
        let e = st.entries.get_mut(&key.id())?;
        e.pins += 1;
        Some(PinGuard { store: Arc::clone(self), id: key.id() })
    }

    /// Drops LRU unpinned entries until `used ≤ budget`.  If everything
    /// left is pinned the store is allowed to run over budget — in-use
    /// artifacts are never sacrificed.
    fn evict_to_fit(&self, st: &mut State) {
        while st.used > self.budget {
            let victim = st
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(id, _)| id.clone());
            let Some(id) = victim else { break };
            let e = st.entries.remove(&id).unwrap();
            st.used -= e.bytes;
            let (class, hash) = id.split_once('/').expect("store ids are class/hash");
            if let Some(c) = ArtifactClass::parse(class) {
                st.classes[class_ix(c)].evictions += 1;
                std::fs::remove_file(
                    self.root.join(c.dir_name()).join(format!("{hash}.blob")),
                )
                .ok();
            }
            self.metrics.incr("store_evictions", 1);
        }
    }

    fn sync_gauges(&self, st: &State) {
        self.metrics.set("store_bytes", st.used as u64);
        self.metrics.set("store_entries", st.entries.len() as u64);
    }

    /// Per-class monotone counters + current residency (used by the
    /// result-cache view to keep the legacy `cache_*` gauges alive).
    pub fn class_stats(&self, class: ArtifactClass) -> ClassStats {
        let st = self.state.lock().unwrap();
        let prefix = format!("{}/", class.dir_name());
        let (mut used, mut entries) = (0usize, 0usize);
        for (id, e) in st.entries.iter() {
            if id.starts_with(&prefix) {
                used += e.bytes;
                entries += 1;
            }
        }
        let c = &st.classes[class_ix(class)];
        ClassStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            used_bytes: used,
            entries,
        }
    }

    /// Total resident bytes (all classes).
    pub fn used_bytes(&self) -> usize {
        self.state.lock().unwrap().used
    }
}

/// RAII pin: the artifact stays resident until the guard drops.
pub struct PinGuard {
    store: Arc<ArtifactStore>,
    id: String,
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        let mut st = self.store.state.lock().unwrap();
        if let Some(e) = st.entries.get_mut(&self.id) {
            e.pins = e.pins.saturating_sub(1);
        }
        // A pinned store may sit over budget; settle it now.
        self.store.evict_to_fit(&mut st);
        self.store.sync_gauges(&st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmproot(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("exatensor_store_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn pkey(seed: u64) -> StageKey {
        StageKey::proxies(seed, [8, 8, 8], [4, 4, 4], 2, 2, 0, false, [4, 4, 4], "batched")
    }

    fn tensors(fill: f32) -> Vec<DenseTensor> {
        vec![DenseTensor::from_vec([4, 4, 4], vec![fill; 64])]
    }

    fn open(root: &PathBuf, budget: usize) -> (Arc<ArtifactStore>, Arc<Metrics>) {
        let m = Arc::new(Metrics::new());
        let s = Arc::new(ArtifactStore::open(root.clone(), budget, Arc::clone(&m)).unwrap());
        (s, m)
    }

    #[test]
    fn publish_get_round_trip_and_reopen_rescan() {
        let root = tmproot("roundtrip");
        let (s, m) = open(&root, 1 << 20);
        let k = pkey(1);
        assert!(s.get(&k).is_none(), "cold store misses");
        assert!(s.publish(&k, &tensors(1.5), &Json::Null).unwrap());
        let back = s.get(&k).unwrap();
        assert_eq!(back[0].data(), tensors(1.5)[0].data());
        assert_eq!(m.counter("store_hits_compress"), 1);
        assert_eq!(m.counter("store_publishes"), 1);
        assert!(m.counter("store_bytes") > 0);
        drop(s);
        // A fresh store over the same root rebuilds the index from disk.
        let (s2, m2) = open(&root, 1 << 20);
        assert!(s2.contains(&k), "reopen must rescan published blobs");
        assert!(s2.get(&k).is_some());
        assert_eq!(m2.counter("store_hits_compress"), 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn concurrent_duplicate_publish_yields_one_blob() {
        let root = tmproot("race");
        let (s, _m) = open(&root, 1 << 20);
        let k = pkey(2);
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&s);
                let k = k.clone();
                std::thread::spawn(move || s.publish(&k, &tensors(2.0), &Json::Null).unwrap())
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // Last atomic rename wins; the index holds exactly one entry and
        // the bytes are counted once.
        let st = s.class_stats(ArtifactClass::Proxies);
        assert_eq!(st.entries, 1, "duplicate publishes must collapse to one blob");
        assert_eq!(st.used_bytes, s.used_bytes());
        let files: Vec<_> = std::fs::read_dir(root.join("proxies"))
            .unwrap()
            .flatten()
            .collect();
        assert_eq!(files.len(), 1, "one file on disk");
        assert_eq!(s.get(&k).unwrap()[0].data(), tensors(2.0)[0].data());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let root = tmproot("lru");
        // Each 64-float blob is a few hundred bytes; budget fits two.
        let (s, _) = open(&root, 1 << 20);
        let probe = pkey(0);
        s.publish(&probe, &tensors(0.0), &Json::Null).unwrap();
        let one = s.used_bytes();
        drop(s);
        std::fs::remove_dir_all(&root).ok();

        let (s, m2) = open(&root, one * 2 + one / 2);
        let (a, b, c) = (pkey(10), pkey(11), pkey(12));
        s.publish(&a, &tensors(1.0), &Json::Null).unwrap();
        s.publish(&b, &tensors(2.0), &Json::Null).unwrap();
        // Touch `a` so `b` is LRU, then `c` must evict `b`.
        assert!(s.get(&a).is_some());
        s.publish(&c, &tensors(3.0), &Json::Null).unwrap();
        assert!(s.contains(&a) && s.contains(&c));
        assert!(!s.contains(&b), "LRU entry must be evicted");
        assert_eq!(m2.counter("store_evictions"), 1);
        assert!(s.used_bytes() <= one * 2 + one / 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn eviction_never_removes_a_pinned_artifact() {
        let root = tmproot("pin");
        let (s, _) = open(&root, 1 << 20);
        s.publish(&pkey(0), &tensors(0.0), &Json::Null).unwrap();
        let one = s.used_bytes();
        drop(s);
        std::fs::remove_dir_all(&root).ok();

        // Budget holds one blob only.
        let (s, m) = open(&root, one + one / 2);
        let (a, b, c) = (pkey(20), pkey(21), pkey(22));
        s.publish(&a, &tensors(1.0), &Json::Null).unwrap();
        let guard = s.pin(&a).expect("resident artifact pins");
        // Publishing `b` exceeds the budget, but `a` is pinned: the store
        // runs over budget rather than evicting in-use work.
        s.publish(&b, &tensors(2.0), &Json::Null).unwrap();
        assert!(s.contains(&a), "pinned artifact must survive eviction pressure");
        assert!(s.used_bytes() > one + one / 2, "store may run over budget while pinned");
        drop(guard);
        // With the pin gone the guard's drop settles the budget.
        assert!(s.used_bytes() <= one + one / 2);
        // And `a` (older) is fair game for the next publish's eviction.
        s.publish(&c, &tensors(3.0), &Json::Null).unwrap();
        assert!(!s.contains(&a) || !s.contains(&b), "unpinned LRU entries evict again");
        assert!(m.counter("store_evictions") >= 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_blob_is_quarantined_and_recomputable() {
        let root = tmproot("corrupt");
        let (s, m) = open(&root, 1 << 20);
        let k = pkey(30);
        s.publish(&k, &tensors(4.0), &Json::Null).unwrap();
        // Flip one payload byte on disk behind the store's back.
        let path = root.join("proxies").join(format!("{}.blob", k.hash));
        let mut raw = std::fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0x01;
        std::fs::write(&path, &raw).unwrap();
        // The digest check catches it: miss, quarantine, counter.
        assert!(s.get(&k).is_none(), "corrupt blob must read as a miss");
        assert_eq!(m.counter("store_corrupt"), 1);
        assert!(!s.contains(&k));
        assert!(!path.exists(), "corrupt blob must leave the addressable tree");
        let quarantined: Vec<_> = std::fs::read_dir(root.join("quarantine"))
            .unwrap()
            .flatten()
            .collect();
        assert_eq!(quarantined.len(), 1, "bad bytes kept for post-mortem");
        // Recompute path: publish again, get hits again.
        assert!(s.publish(&k, &tensors(4.0), &Json::Null).unwrap());
        assert_eq!(s.get(&k).unwrap()[0].data(), tensors(4.0)[0].data());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn zero_budget_disables_the_store() {
        let root = tmproot("disabled");
        let (s, m) = open(&root, 0);
        let k = pkey(40);
        assert!(!s.publish(&k, &tensors(1.0), &Json::Null).unwrap());
        assert!(s.get(&k).is_none());
        assert_eq!(m.counter("store_publishes"), 0);
        assert_eq!(s.class_stats(ArtifactClass::Proxies).misses, 1);
        std::fs::remove_dir_all(&root).ok();
    }
}
