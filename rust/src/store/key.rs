//! Typed stage keys for the artifact store.
//!
//! Salsa-style: an artifact is addressed by the FNV-1a digest of *(input
//! digest, stage-config subset)* — only the fields that can change the
//! artifact's bits enter the key.  Execution-only knobs (threads, I/O
//! depth, map tier, recovery solver, …) are excluded by construction, so
//! a resubmit that differs only in how the work executes lands on the
//! same artifact.
//!
//! Three classes exist:
//!
//! * [`ArtifactClass::Proxies`] — a compressed proxy set (Stage 1 output).
//!   Keyed by the source fingerprint plus everything that shapes the
//!   compression sum: dims, reduced dims, replica count, anchor rows, map
//!   seed, precision, the block grid (the fold order of float addition),
//!   and the compressor path.  **Rank is deliberately absent** — rank only
//!   enters the proxy ALS, so a rank sweep shares one proxy artifact.
//! * [`ArtifactClass::ShardAccum`] — one replica of one raw shard
//!   accumulator from the sharded plane, keyed by the owning proxy key
//!   plus (shard, replica).
//! * [`ArtifactClass::Factors`] — a final factor set, keyed by the serve
//!   plane's whole-job cache key (`serve::cache::cache_key`).

use crate::util::hash::Fnv;

/// Which kind of artifact a key addresses.  Each class lives in its own
/// subdirectory of the store root so digests can never collide across
/// classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactClass {
    Proxies,
    ShardAccum,
    Factors,
}

impl ArtifactClass {
    pub fn dir_name(&self) -> &'static str {
        match self {
            ArtifactClass::Proxies => "proxies",
            ArtifactClass::ShardAccum => "shards",
            ArtifactClass::Factors => "factors",
        }
    }

    pub fn parse(s: &str) -> Option<ArtifactClass> {
        Some(match s {
            "proxies" => ArtifactClass::Proxies,
            "shards" => ArtifactClass::ShardAccum,
            "factors" => ArtifactClass::Factors,
            _ => None,
        })
    }
}

/// A fully derived store address: class + 16-hex content key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct StageKey {
    pub class: ArtifactClass,
    pub hash: String,
}

impl StageKey {
    /// The index/display form, e.g. `proxies/0123456789abcdef`.
    pub fn id(&self) -> String {
        format!("{}/{}", self.class.dir_name(), self.hash)
    }

    /// Key for a compressed proxy set.  `path` is the pipeline's
    /// compressor partition tag (`"batched"`, `"plain:<name>"`): two
    /// compressors may sum blocks in different orders, so their proxies
    /// are distinct artifacts even on the same input.
    #[allow(clippy::too_many_arguments)]
    pub fn proxies(
        source_fp: u64,
        dims: [usize; 3],
        reduced: [usize; 3],
        replicas: usize,
        anchor: usize,
        seed: u64,
        mixed_precision: bool,
        block: [usize; 3],
        path: &str,
    ) -> StageKey {
        let mut h = Fnv::new();
        h.write(b"proxies-v1");
        h.write_u64(source_fp);
        for d in dims.iter().chain(&reduced).chain(&block) {
            h.write_u64(*d as u64);
        }
        h.write_u64(replicas as u64);
        h.write_u64(anchor as u64);
        h.write_u64(seed);
        h.write_u64(mixed_precision as u64);
        h.write(path.as_bytes());
        StageKey {
            class: ArtifactClass::Proxies,
            hash: format!("{:016x}", h.finish()),
        }
    }

    /// Key for one replica of one raw shard accumulator.  Derived from
    /// the owning proxy key so every compression-shaping field is
    /// inherited for free.
    pub fn shard_accum(proxy: &StageKey, shard: usize, replica: usize) -> StageKey {
        debug_assert_eq!(proxy.class, ArtifactClass::Proxies);
        let mut h = Fnv::new();
        h.write(b"shard-v1");
        h.write(proxy.hash.as_bytes());
        h.write_u64(shard as u64);
        h.write_u64(replica as u64);
        StageKey {
            class: ArtifactClass::ShardAccum,
            hash: format!("{:016x}", h.finish()),
        }
    }

    /// Key for a final factor set — the serve plane's whole-job cache key
    /// verbatim (already a 16-hex FNV digest over source + result-relevant
    /// config).
    pub fn factors(cache_key: &str) -> StageKey {
        StageKey {
            class: ArtifactClass::Factors,
            hash: cache_key.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> StageKey {
        StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 5, 6, 3, false, [16, 16, 16], "batched")
    }

    #[test]
    fn proxy_key_ignores_nothing_it_hashes() {
        let k = base();
        assert_eq!(k, base(), "derivation is deterministic");
        // Every hashed field must split the key.
        let variants = [
            StageKey::proxies(8, [40, 40, 40], [8, 8, 8], 5, 6, 3, false, [16, 16, 16], "batched"),
            StageKey::proxies(7, [41, 40, 40], [8, 8, 8], 5, 6, 3, false, [16, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [9, 8, 8], 5, 6, 3, false, [16, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 6, 6, 3, false, [16, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 5, 7, 3, false, [16, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 5, 6, 4, false, [16, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 5, 6, 3, true, [16, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 5, 6, 3, false, [8, 16, 16], "batched"),
            StageKey::proxies(7, [40, 40, 40], [8, 8, 8], 5, 6, 3, false, [16, 16, 16], "plain:x"),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(&k, v, "variant {i} must change the key");
        }
    }

    #[test]
    fn shard_keys_are_distinct_per_slot() {
        let p = base();
        let a = StageKey::shard_accum(&p, 0, 0);
        assert_eq!(a.class, ArtifactClass::ShardAccum);
        assert_ne!(a, StageKey::shard_accum(&p, 1, 0));
        assert_ne!(a, StageKey::shard_accum(&p, 0, 1));
        assert_eq!(a, StageKey::shard_accum(&p, 0, 0));
    }

    #[test]
    fn ids_namespace_by_class() {
        let p = base();
        assert!(p.id().starts_with("proxies/"));
        assert!(StageKey::factors(&p.hash).id().starts_with("factors/"));
        assert_ne!(p.id(), StageKey::factors(&p.hash).id());
        assert_eq!(ArtifactClass::parse("shards"), Some(ArtifactClass::ShardAccum));
        assert_eq!(ArtifactClass::parse("bogus"), None);
    }
}
