//! Offline shim for the `log` facade crate.
//!
//! Implements the subset the workspace uses: the [`Level`]/[`LevelFilter`]
//! types (with cross-type ordering), the [`Log`] trait, the global
//! logger/level registry, and the `error!`/`warn!`/`info!`/`debug!`/
//! `trace!` macros.  Semantics match the real crate for this subset:
//! `set_logger` succeeds once, levels filter before dispatch, and macros
//! record `module_path!()` as the target.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log severity, most severe first (matches the real crate's ordering:
/// `Error < Warn < ... < Trace` numerically).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Level filter: `Off` plus one variant per [`Level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a log request: level + target (module path).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted message arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

/// A logger backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Installs the global logger; fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Returns the installed logger (a no-op logger before [`set_logger`]).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP as &'static dyn Log,
    }
}

/// Sets the global maximum level; records above it are dropped early.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro back-end: filters on the global level, then dispatches.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        let metadata = Metadata { level, target };
        let logger = logger();
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    struct Capture {
        lines: Mutex<Vec<String>>,
    }

    impl Log for Capture {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }

        fn log(&self, record: &Record) {
            self.lines
                .lock()
                .unwrap()
                .push(format!("{} {}", record.level(), record.args()));
        }

        fn flush(&self) {}
    }

    static CAPTURE: OnceLock<Capture> = OnceLock::new();

    #[test]
    fn levels_order_and_filter() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(Level::Warn <= LevelFilter::Trace);
    }

    #[test]
    fn logger_roundtrip() {
        let cap = CAPTURE.get_or_init(|| Capture {
            lines: Mutex::new(Vec::new()),
        });
        let _ = set_logger(cap);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 42);
        debug!("filtered out");
        let lines = cap.lines.lock().unwrap();
        assert!(lines.iter().any(|l| l == "INFO hello 42"));
        assert!(!lines.iter().any(|l| l.contains("filtered out")));
    }
}
