//! Offline **stub** of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links libxla/PJRT, which cannot be fetched or built in
//! this environment.  This stub mirrors the API surface that
//! `runtime::executor` uses so the `xla` cargo feature still compiles;
//! every entry point returns an error, which the executor surfaces as a
//! clean startup failure ("runtime unavailable") that all artifact tests
//! and benches already self-skip on.  Swap this directory for a real
//! xla-rs checkout (same package name) to execute AOT artifacts.

use std::fmt;
use std::path::Path;

/// Error type for every stub operation.
#[derive(Debug)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB: &str = "built against the vendored xla stub; replace vendor/xla with a real xla-rs checkout";

/// PJRT client handle (stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error(STUB))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error(STUB))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB))
    }
}

/// Host literal (stub).
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Self(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error(STUB))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error(STUB))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error(STUB))
    }
}
