//! Offline shim for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! provides the (small) subset of anyhow's API that the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.  Error values
//! are flattened to strings with their context chain joined by `": "`,
//! which is what our logs and test assertions rely on.

use std::fmt;

/// A string-backed error value.  Like the real `anyhow::Error`, this type
/// deliberately does **not** implement `std::error::Error`, which is what
/// makes the blanket `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Creates an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
        }
    }

    /// Prepends a context layer (`"context: cause"`), mirroring how the
    /// real anyhow renders a context chain in its `{:#}` format.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Adds `.context(...)` / `.with_context(...)` to `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Constructs an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Returns early with an [`Error`] built from the arguments.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Returns early with an error if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains() {
        let e = io_fail().context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let some = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(some, 7);
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed (got 0)");
        let e: Error = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }
}
