//! `BENCH_gemm_mttkrp` — serial-vs-parallel kernel throughput tracked
//! from the ComputeBackend PR onward.
//!
//! Sweeps the `CpuParallelBackend` over 1/2/4/8 worker threads against the
//! serial reference on the `kernel_micro` shapes:
//!
//! * GEMM 256×256×256 (the blocked-kernel headline shape);
//! * GEMM 512×64×512 (the fat-unfolding × tall-skinny compression shape);
//! * MTTKRP on a 96³ tensor at rank 16 (the ALS hot spot: `I × JK` times
//!   `JK × R`).
//!
//! Emits a markdown table plus machine-readable JSON at both
//! `bench_results/BENCH_gemm_mttkrp.json` and `BENCH_gemm_mttkrp.json`
//! (the tracked perf-trajectory file).

use exascale_tensor::bench_harness::{bench, gflops, speedup, Report};
use exascale_tensor::linalg::{ComputeBackend, CpuParallelBackend, Matrix, SerialBackend, Trans};
use exascale_tensor::tensor::unfold::unfold_1;
use exascale_tensor::tensor::DenseTensor;
use exascale_tensor::util::rng::Xoshiro256;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(4242);
    let mut rep = Report::new(
        "BENCH_gemm_mttkrp",
        "serial vs parallel GEMM/MTTKRP throughput (ComputeBackend)",
    );

    // ── GEMM shapes ──
    for (m, k, n) in [(256usize, 256usize, 256usize), (512, 64, 512)] {
        let a = Matrix::random_normal(m, k, &mut rng);
        let b = Matrix::random_normal(k, n, &mut rng);
        let flops = 2.0 * (m * n * k) as f64;

        let serial = bench(&format!("gemm_{m}x{k}x{n}_serial"), 5, 1.0, || {
            SerialBackend.matmul(&a, Trans::No, &b, Trans::No)
        });
        let serial_s = serial.mean_s;
        println!(
            "gemm {m}×{k}×{n} serial: {:.3} ms ({:.2} GF/s)",
            serial_s * 1e3,
            gflops(flops, serial_s)
        );
        let g = gflops(flops, serial_s);
        rep.push(serial.with_threads(1).with_extra("gflops", g).with_extra("speedup", 1.0));

        for &t in &THREAD_SWEEP[1..] {
            let be = CpuParallelBackend::new(t);
            let meas = bench(&format!("gemm_{m}x{k}x{n}_par{t}"), 5, 1.0, || {
                be.matmul(&a, Trans::No, &b, Trans::No)
            });
            let sp = speedup(serial_s, meas.mean_s);
            println!(
                "gemm {m}×{k}×{n} par×{t}:  {:.3} ms ({:.2} GF/s, {sp:.2}x)",
                meas.mean_s * 1e3,
                gflops(flops, meas.mean_s)
            );
            let g = gflops(flops, meas.mean_s);
            rep.push(meas.with_threads(t).with_extra("gflops", g).with_extra("speedup", sp));
        }
    }

    // ── MTTKRP: 96³ tensor, rank 16 ──
    let (dim, rank) = (96usize, 16usize);
    let t3 = DenseTensor::random_normal([dim, dim, dim], &mut rng);
    let x1 = unfold_1(&t3);
    let bfac = Matrix::random_normal(dim, rank, &mut rng);
    let cfac = Matrix::random_normal(dim, rank, &mut rng);
    // X₁ (I × JK) · KR (JK × R): 2·I·JK·R flops plus the KR formation.
    let flops = 2.0 * (dim * dim * dim * rank) as f64;

    let serial = bench("mttkrp_96_r16_serial", 5, 1.0, || {
        SerialBackend.mttkrp(1, &x1, &cfac, &bfac)
    });
    let serial_s = serial.mean_s;
    println!(
        "mttkrp 96³ r16 serial: {:.3} ms ({:.2} GF/s)",
        serial_s * 1e3,
        gflops(flops, serial_s)
    );
    let g = gflops(flops, serial_s);
    rep.push(serial.with_threads(1).with_extra("gflops", g).with_extra("speedup", 1.0));

    for &t in &THREAD_SWEEP[1..] {
        let be = CpuParallelBackend::new(t);
        let meas = bench(&format!("mttkrp_96_r16_par{t}"), 5, 1.0, || {
            be.mttkrp(1, &x1, &cfac, &bfac)
        });
        let sp = speedup(serial_s, meas.mean_s);
        println!(
            "mttkrp 96³ r16 par×{t}:  {:.3} ms ({:.2} GF/s, {sp:.2}x)",
            meas.mean_s * 1e3,
            gflops(flops, meas.mean_s)
        );
        let g = gflops(flops, meas.mean_s);
        rep.push(meas.with_threads(t).with_extra("gflops", g).with_extra("speedup", sp));
    }

    rep.finish();
    match rep.save_as("BENCH_gemm_mttkrp.json") {
        Ok(()) => println!("(saved BENCH_gemm_mttkrp.json)"),
        Err(e) => eprintln!("warning: could not save BENCH_gemm_mttkrp.json: {e}"),
    }
}
