//! `BENCH_gemm_mttkrp` — kernel throughput *and* allocator traffic tracked
//! from the ComputeBackend PR onward.
//!
//! Sweeps the `CpuParallelBackend` over worker threads against the serial
//! reference, and — the point of the fused-MTTKRP PR — benches the fused
//! zero-materialization MTTKRP against the `khatri_rao`+GEMM oracle with a
//! counting global allocator attributing bytes to each call:
//!
//! * GEMM 256×256×256 (the blocked-kernel headline shape);
//! * GEMM 512×64×512 (the fat-unfolding × tall-skinny compression shape);
//! * MTTKRP on a 96³ tensor at rank 16 (the ALS hot spot), `materialized`
//!   vs `fused_serial` vs `fused_par{t}`.
//!
//! Each MTTKRP row carries `alloc_bytes` (heap bytes requested per call)
//! and `alloc_peak_bytes` (transient high-water above entry).  The run
//! **asserts** the fused path never allocates the `(J·K)×R` Khatri-Rao
//! intermediate — per-call bytes and peak both strictly below the buffer
//! the materialized arm cannot avoid — so an allocation regression fails
//! the bench (and the CI smoke job) instead of silently rotting.
//!
//! `--quick` bounds sizes/iterations for CI smoke; the full run emits a
//! markdown table plus machine-readable JSON at both
//! `bench_results/BENCH_gemm_mttkrp.json` and `BENCH_gemm_mttkrp.json`
//! (the tracked perf-trajectory file).

use exascale_tensor::bench_harness::{bench, gflops, speedup, Measurement, Report};
use exascale_tensor::linalg::{
    mttkrp_materialized, ComputeBackend, CpuParallelBackend, Matrix, SerialBackend, Trans,
};
use exascale_tensor::tensor::unfold::unfold_1;
use exascale_tensor::tensor::DenseTensor;
use exascale_tensor::util::alloc::CountingAlloc;
use exascale_tensor::util::rng::Xoshiro256;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Runs `f` once after a warmup call and returns
/// `(bytes allocated, transient peak above entry)` for the measured call.
/// The warmup absorbs one-time growth (thread-local pack arenas, Vec
/// high-water marks) so steady-state traffic is what's attributed.
fn alloc_profile<T>(mut f: impl FnMut() -> T) -> (f64, f64) {
    // Several warmup rounds: parallel arms hand chunks to whichever pool
    // workers are free, so one round is not guaranteed to touch (and grow)
    // every worker's thread-local pack arena.
    for _ in 0..3 {
        let _ = f();
    }
    ALLOC.reset_peak();
    let live_before = ALLOC.live_bytes();
    let bytes_before = ALLOC.allocated_bytes();
    let out = f();
    let bytes = ALLOC.allocated_bytes().saturating_sub(bytes_before) as f64;
    let peak = ALLOC.peak_bytes().saturating_sub(live_before) as f64;
    drop(out);
    (bytes, peak)
}

fn push_with_gflops(rep: &mut Report, m: Measurement, flops: f64, baseline_s: f64, threads: usize) {
    let g = gflops(flops, m.mean_s);
    let sp = speedup(baseline_s, m.mean_s);
    rep.push(m.with_threads(threads).with_extra("gflops", g).with_extra("speedup", sp));
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (min_iters, budget_s) = if quick { (2usize, 0.2f64) } else { (5, 1.0) };
    let thread_sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let gemm_shapes: &[(usize, usize, usize)] = if quick {
        &[(96, 96, 96)]
    } else {
        &[(256, 256, 256), (512, 64, 512)]
    };
    let (dim, rank) = if quick { (48usize, 8usize) } else { (96, 16) };

    let mut rng = Xoshiro256::seed_from_u64(4242);
    let mut rep = Report::new(
        "BENCH_gemm_mttkrp",
        "serial vs parallel GEMM + fused vs materialized MTTKRP (ComputeBackend)",
    );

    // ── GEMM shapes ──
    for &(m, k, n) in gemm_shapes {
        let a = Matrix::random_normal(m, k, &mut rng);
        let b = Matrix::random_normal(k, n, &mut rng);
        let flops = 2.0 * (m * n * k) as f64;

        let serial = bench(&format!("gemm_{m}x{k}x{n}_serial"), min_iters, budget_s, || {
            SerialBackend.matmul(&a, Trans::No, &b, Trans::No)
        });
        let serial_s = serial.mean_s;
        println!(
            "gemm {m}×{k}×{n} serial: {:.3} ms ({:.2} GF/s)",
            serial_s * 1e3,
            gflops(flops, serial_s)
        );
        push_with_gflops(&mut rep, serial, flops, serial_s, 1);

        for &t in &thread_sweep[1..] {
            // Threshold 0: always measure the strip-split path itself —
            // quick-mode shapes sit below the serial-fallback cutoff, and a
            // "parallel" row that silently benched the serial branch would
            // defeat the CI smoke job.
            let be = CpuParallelBackend::new(t).with_min_par_flops(0);
            let meas = bench(&format!("gemm_{m}x{k}x{n}_par{t}"), min_iters, budget_s, || {
                be.matmul(&a, Trans::No, &b, Trans::No)
            });
            println!(
                "gemm {m}×{k}×{n} par×{t}:  {:.3} ms ({:.2} GF/s, {:.2}x)",
                meas.mean_s * 1e3,
                gflops(flops, meas.mean_s),
                speedup(serial_s, meas.mean_s)
            );
            push_with_gflops(&mut rep, meas, flops, serial_s, t);
        }
    }

    // ── MTTKRP: dim³ tensor at `rank` — fused vs materialized ──
    let t3 = DenseTensor::random_normal([dim, dim, dim], &mut rng);
    let x1 = unfold_1(&t3);
    let bfac = Matrix::random_normal(dim, rank, &mut rng);
    let cfac = Matrix::random_normal(dim, rank, &mut rng);
    // X₁ (I × JK) · KR (JK × R): 2·I·JK·R flops either way; the
    // materialized arm additionally forms the JK × R Khatri-Rao buffer.
    let flops = 2.0 * (dim * dim * dim * rank) as f64;
    let kr_bytes = (dim * dim * rank * std::mem::size_of::<f32>()) as f64;

    let (mat_bytes, mat_peak) = alloc_profile(|| mttkrp_materialized(&x1, &cfac, &bfac));
    let mat = bench(&format!("mttkrp_{dim}_r{rank}_materialized"), min_iters, budget_s, || {
        mttkrp_materialized(&x1, &cfac, &bfac)
    });
    let mat_s = mat.mean_s;
    println!(
        "mttkrp {dim}³ r{rank} materialized: {:.3} ms ({:.2} GF/s, {:.0} KB/call, peak {:.0} KB)",
        mat_s * 1e3,
        gflops(flops, mat_s),
        mat_bytes / 1024.0,
        mat_peak / 1024.0
    );
    let row = mat.with_extra("alloc_bytes", mat_bytes).with_extra("alloc_peak_bytes", mat_peak);
    push_with_gflops(&mut rep, row, flops, mat_s, 1);

    let (fused_bytes, fused_peak) = alloc_profile(|| SerialBackend.mttkrp(1, &x1, &cfac, &bfac));
    let fused = bench(&format!("mttkrp_{dim}_r{rank}_fused_serial"), min_iters, budget_s, || {
        SerialBackend.mttkrp(1, &x1, &cfac, &bfac)
    });
    println!(
        "mttkrp {dim}³ r{rank} fused serial: {:.3} ms ({:.2} GF/s, {:.2}x, {:.0} KB/call, peak {:.0} KB)",
        fused.mean_s * 1e3,
        gflops(flops, fused.mean_s),
        speedup(mat_s, fused.mean_s),
        fused_bytes / 1024.0,
        fused_peak / 1024.0
    );
    let row = fused
        .with_extra("alloc_bytes", fused_bytes)
        .with_extra("alloc_peak_bytes", fused_peak);
    push_with_gflops(&mut rep, row, flops, mat_s, 1);

    // The memory claim, asserted: the fused path must never allocate the
    // (J·K)×R Khatri-Rao intermediate the materialized arm cannot avoid.
    assert!(
        fused_bytes < kr_bytes,
        "fused MTTKRP allocated {fused_bytes} B/call — at least the {kr_bytes} B Khatri-Rao \
         buffer it exists to avoid"
    );
    assert!(
        fused_peak < mat_peak,
        "fused MTTKRP peak {fused_peak} B not below materialized peak {mat_peak} B"
    );
    assert!(
        mat_bytes >= kr_bytes,
        "materialized arm allocated {mat_bytes} B/call — did it stop forming the \
         {kr_bytes} B Khatri-Rao buffer? Update the bench arms"
    );
    println!(
        "alloc win asserted: fused {:.0} KB/call vs materialized {:.0} KB/call (KR buffer {:.0} KB)",
        fused_bytes / 1024.0,
        mat_bytes / 1024.0,
        kr_bytes / 1024.0
    );

    for &t in &thread_sweep[1..] {
        // Threshold 0: see the GEMM sweep — the panel/row split must be
        // what's measured, not the serial fallback.
        let be = CpuParallelBackend::new(t).with_min_par_flops(0);
        let (par_bytes, par_peak) = alloc_profile(|| be.mttkrp(1, &x1, &cfac, &bfac));
        let meas = bench(&format!("mttkrp_{dim}_r{rank}_fused_par{t}"), min_iters, budget_s, || {
            be.mttkrp(1, &x1, &cfac, &bfac)
        });
        println!(
            "mttkrp {dim}³ r{rank} fused par×{t}:  {:.3} ms ({:.2} GF/s, {:.2}x, peak {:.0} KB)",
            meas.mean_s * 1e3,
            gflops(flops, meas.mean_s),
            speedup(mat_s, meas.mean_s),
            par_peak / 1024.0
        );
        let row = meas
            .with_extra("alloc_bytes", par_bytes)
            .with_extra("alloc_peak_bytes", par_peak);
        push_with_gflops(&mut rep, row, flops, mat_s, t);
    }

    rep.finish();
    if quick {
        // Quick rows (bounded shapes, truncated sweep) are not comparable
        // to the tracked trajectory — never overwrite it from CI smoke.
        println!("(--quick: not overwriting BENCH_gemm_mttkrp.json)");
    } else {
        match rep.save_as("BENCH_gemm_mttkrp.json") {
            Ok(()) => println!("(saved BENCH_gemm_mttkrp.json)"),
            Err(e) => eprintln!("warning: could not save BENCH_gemm_mttkrp.json: {e}"),
        }
    }
}
