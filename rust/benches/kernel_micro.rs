//! Kernel microbenchmarks: the L1/L3 hot paths in isolation.
//!
//! * blocked rust GEMM vs naive (validates the §Perf cache-blocking);
//! * mixed-precision emulation cost (split + 3 GEMMs vs 1);
//! * TTM-chain block compression: rust vs the AOT Pallas artifact;
//! * single `als_sweep` artifact execution latency (the request-path unit).

use exascale_tensor::bench_harness::{bench, gflops, speedup, Report};
use exascale_tensor::compress::comp_dense;
use exascale_tensor::linalg::{matmul, ComputeBackend, CpuParallelBackend, Matrix, Trans};
use exascale_tensor::mixed::{matmul_mixed, MixedPrecision};
use exascale_tensor::runtime::{artifacts_dir, HostTensor, XlaRuntime};
use exascale_tensor::tensor::DenseTensor;
use exascale_tensor::util::rng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(1234);
    let mut rep = Report::new("kernel_micro", "kernel microbenchmarks");

    // ── GEMM 256³ ──
    let a = Matrix::random_normal(256, 256, &mut rng);
    let b = Matrix::random_normal(256, 256, &mut rng);
    let m = bench("gemm_256_blocked", 5, 1.0, || {
        matmul(&a, Trans::No, &b, Trans::No)
    });
    let serial_s = m.mean_s;
    let flops = 2.0 * 256f64.powi(3);
    let gf = gflops(flops, m.mean_s);
    println!("gemm 256³ blocked: {:.3} ms ({gf:.2} GF/s)", m.mean_s * 1e3);
    rep.push(m.with_extra("gflops", gf));

    // Parallel ComputeBackend on the same shape (full sweep lives in the
    // gemm_mttkrp bench; this row keeps the headline number here).
    let be4 = CpuParallelBackend::new(4);
    let m = bench("gemm_256_parallel_t4", 5, 1.0, || {
        be4.matmul(&a, Trans::No, &b, Trans::No)
    });
    let sp = speedup(serial_s, m.mean_s);
    let gf = gflops(flops, m.mean_s);
    println!("gemm 256³ parallel×4: {:.3} ms ({gf:.2} GF/s, {sp:.2}x)", m.mean_s * 1e3);
    rep.push(m.with_extra("gflops", gf).with_extra("speedup", sp));

    // ── mixed-precision emulation ──
    let m = bench("mixed_matmul_256_rust", 5, 1.0, || {
        matmul_mixed(&a, &b, MixedPrecision::Bf16)
    });
    println!("mixed (bf16 split) rust: {:.3} ms", m.mean_s * 1e3);
    rep.push(m);

    // ── TTM block compression, rust ──
    let t = DenseTensor::random_normal([32, 32, 32], &mut rng);
    let u = Matrix::random_normal(16, 32, &mut rng);
    let v = Matrix::random_normal(16, 32, &mut rng);
    let w = Matrix::random_normal(16, 32, &mut rng);
    let m = bench("compress_block_rust_d32", 10, 1.0, || {
        comp_dense(&t, &u, &v, &w, MixedPrecision::Full)
    });
    println!("compress block d=32 rust: {:.3} ms", m.mean_s * 1e3);
    rep.push(m);

    // ── XLA artifacts (if built) ──
    match XlaRuntime::load(artifacts_dir(), 1) {
        Ok(rt) => {
            let th = HostTensor::from_tensor(&t);
            let uh = HostTensor::from_matrix(&u);
            let vh = HostTensor::from_matrix(&v);
            let wh = HostTensor::from_matrix(&w);
            let m = bench("compress_block_xla_d32", 10, 2.0, || {
                rt.execute(
                    "compress_block_l16m16n16_d32",
                    vec![th.clone(), uh.clone(), vh.clone(), wh.clone()],
                )
                .expect("xla compress")
            });
            println!("compress block d=32 xla (interpret): {:.3} ms", m.mean_s * 1e3);
            rep.push(m);

            let y = HostTensor::from_tensor(&DenseTensor::random_normal([16, 16, 16], &mut rng));
            let fb = HostTensor::from_matrix(&Matrix::random_normal(16, 4, &mut rng));
            let fc = HostTensor::from_matrix(&Matrix::random_normal(16, 4, &mut rng));
            let m = bench("als_sweep_xla_l16_r4", 10, 2.0, || {
                rt.execute("als_sweep_l16m16n16_r4", vec![y.clone(), fb.clone(), fc.clone()])
                    .expect("xla als")
            });
            println!("als sweep l=16 xla: {:.3} ms", m.mean_s * 1e3);
            rep.push(m);

            let ah = HostTensor::from_matrix(&a);
            let bh = HostTensor::from_matrix(&b);
            let m = bench("mixed_matmul_256_xla", 5, 2.0, || {
                rt.execute("mixed_matmul_256", vec![ah.clone(), bh.clone()])
                    .expect("xla mixed")
            });
            println!("mixed matmul 256 xla (pallas interpret): {:.3} ms", m.mean_s * 1e3);
            rep.push(m);
        }
        Err(e) => eprintln!("(xla arms skipped: {e})"),
    }
    rep.finish();
}
