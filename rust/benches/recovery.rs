//! `BENCH_recovery` — the stacked solve's memory trajectory across solvers.
//!
//! Builds exact compressed replicas `A_p = U_p·A` against **procedural**
//! maps (so no `P·L × I` stack exists anywhere), then runs the stacked
//! recovery with the counting global allocator bracketing each solve, and
//! **asserts**:
//!
//! 1. the dense (Cholesky) solver's peak grows ≈ quadratically with `I` —
//!    the `I×I` Gram this PR's iterative path exists to kill;
//! 2. the matrix-free CGNR solver's peak grows only ≈ linearly with `I`
//!    (the `dim×R` right-hand side + `O(dim)` CG state) across a **16×**
//!    sweep that the dense solver could not even attempt;
//! 3. at a common size every solver (Cholesky, CGNR, sketch+polish)
//!    recovers the planted factors, so the memory win is not bought with
//!    a wrong answer.
//!
//! `--quick` bounds sizes for the CI smoke job; failures are hard
//! `assert!`s so a recovery memory regression fails CI instead of rotting.

use exascale_tensor::bench_harness::{bench_once, Report};
use exascale_tensor::compress::{MapSource, MapTier};
use exascale_tensor::coordinator::config::RecoverySolverKind;
use exascale_tensor::coordinator::recovery::{stacked_recover_opts, RecoveryOptions};
use exascale_tensor::cp::CpModel;
use exascale_tensor::linalg::iterative::CgOptions;
use exascale_tensor::linalg::{matmul, Matrix, Trans};
use exascale_tensor::util::alloc::CountingAlloc;
use exascale_tensor::util::rng::Xoshiro256;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Fixed shapes: reduced dims and anchors are pinned; only `P` follows `I`
/// (the identifiability bound `S + P·(L−S) ≥ I` forces it, exactly as the
/// planner does), so peak-memory growth is attributable to the solver.
const L: usize = 32;
const S: usize = 4;
const JK: usize = 64;
const RANK: usize = 2;

fn replicas_for(i_dim: usize) -> usize {
    (i_dim.saturating_sub(S)).div_ceil(L - S) + 2
}

/// `A_p = U_p·A` streamed in column panels — the bench never holds a map
/// bigger than one `L×panel` scratch, same as the pipeline.
fn compress_factor(maps: &MapSource, p: usize, mode: usize, truth: &Matrix) -> Matrix {
    let dim = maps.dims()[mode];
    let l = maps.reduced()[mode];
    let mut fac = Matrix::zeros(l, truth.cols());
    let mut buf = Vec::new();
    let mut a0 = 0;
    while a0 < dim {
        let a1 = (a0 + 256).min(dim);
        let pan = maps.panel(p, mode, a0, a1, std::mem::take(&mut buf));
        let part = matmul(&pan, Trans::No, &truth.slice_rows(a0, a1), Trans::No);
        for c in 0..fac.cols() {
            for (d, s) in fac.col_mut(c).iter_mut().zip(part.col(c)) {
                *d += s;
            }
        }
        buf = pan.into_vec();
        a0 = a1;
    }
    fac
}

struct Fixture {
    truth: CpModel,
    models: Vec<CpModel>,
    maps: MapSource,
}

fn fixture(i_dim: usize) -> Fixture {
    let dims = [i_dim, JK, JK];
    let p = replicas_for(i_dim);
    let maps = MapSource::generate(dims, [L, L, L], p, S, 4242, MapTier::Procedural);
    let mut rng = Xoshiro256::seed_from_u64(900 + i_dim as u64);
    let truth = CpModel::new(
        Matrix::random_normal(dims[0], RANK, &mut rng),
        Matrix::random_normal(dims[1], RANK, &mut rng),
        Matrix::random_normal(dims[2], RANK, &mut rng),
    );
    let models = (0..p)
        .map(|p| {
            CpModel::new(
                compress_factor(&maps, p, 0, &truth.a),
                compress_factor(&maps, p, 1, &truth.b),
                compress_factor(&maps, p, 2, &truth.c),
            )
        })
        .collect();
    Fixture { truth, models, maps }
}

struct Case {
    peak_bytes: usize,
    model: CpModel,
}

/// Measures one stacked solve: the fixture (truth, replicas, map spec) is
/// live before the bracket, so `peak − live0` is the *solver's* footprint —
/// Gram + factorization for Cholesky, RHS + `O(dim)` CG state for CGNR.
fn run_case(rep: &mut Report, fx: &Fixture, solver: RecoverySolverKind) -> Case {
    let i_dim = fx.maps.dims()[0];
    let opts = RecoveryOptions {
        solver,
        // A slightly looser tolerance than the pipeline default: the bench
        // compares against the planted truth, not bitwise against an
        // oracle, and fewer sweeps keep the 16× case CI-sized.
        cg: CgOptions { tol: 1e-4, ..CgOptions::default() },
        ..RecoveryOptions::default()
    };
    ALLOC.reset_peak();
    let live0 = ALLOC.live_bytes();
    let name = format!("recovery_{}_{i_dim}", solver.as_str());
    let (meas, out) =
        bench_once(&name, || stacked_recover_opts(&fx.models, &fx.maps, &opts).unwrap());
    let peak_bytes = ALLOC.peak_bytes().saturating_sub(live0);
    let (model, stats) = out;
    let err = model.a.rel_error(&fx.truth.a);
    println!(
        "{name}: peak {} KiB, {} cg iters, A err {err:.2e}",
        peak_bytes >> 10,
        stats.cg_iterations
    );
    assert!(err < 1e-2, "{name}: recovered factors off the planted truth ({err})");
    rep.push(
        meas.with_extra("alloc_peak_bytes", peak_bytes as f64)
            .with_extra("cg_iterations", stats.cg_iterations as f64)
            .with_extra("rel_error_a", err)
            .with_extra("i_dim", i_dim as f64),
    );
    Case { peak_bytes, model }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let i_small: usize = if quick { 128 } else { 256 };
    let i_mid = 4 * i_small;
    let i_big = 16 * i_small;
    let mut rep = Report::new(
        "BENCH_recovery",
        "stacked solve: CGNR alloc peak linear in I where Cholesky is quadratic",
    );

    // Common size: all three solvers must agree with the planted truth
    // (run_case asserts it) and with each other.
    let fx_small = fixture(i_small);
    let chol_small = run_case(&mut rep, &fx_small, RecoverySolverKind::Cholesky);
    let iter_small = run_case(&mut rep, &fx_small, RecoverySolverKind::Iterative);
    let sk_small = run_case(&mut rep, &fx_small, RecoverySolverKind::Sketch);
    let diff = iter_small.model.a.rel_error(&chol_small.model.a);
    assert!(diff < 1e-2, "CGNR vs Cholesky diverge: {diff}");
    let diff = sk_small.model.a.rel_error(&chol_small.model.a);
    assert!(diff < 1e-2, "sketch vs Cholesky diverge: {diff}");

    // 4× I: the dense solver's Gram makes its peak grow ≈ quadratically.
    let fx_mid = fixture(i_mid);
    let chol_mid = run_case(&mut rep, &fx_mid, RecoverySolverKind::Cholesky);
    let iter_mid = run_case(&mut rep, &fx_mid, RecoverySolverKind::Iterative);
    assert!(
        chol_mid.peak_bytes >= 8 * chol_small.peak_bytes,
        "Cholesky peak should scale ~quadratically with I ({} → {} across 4×); \
         if this broke, the contrast baseline is wrong",
        chol_small.peak_bytes,
        chol_mid.peak_bytes
    );
    assert!(
        4 * iter_mid.peak_bytes <= chol_mid.peak_bytes,
        "CGNR peak {} must be ≪ Cholesky {} at I={i_mid}",
        iter_mid.peak_bytes,
        chol_mid.peak_bytes
    );

    // 16× I — a size whose Gram alone would cost I²·4 bytes — runs on the
    // iterative path only, and its peak must stay ≈ linear in I.
    let fx_big = fixture(i_big);
    let iter_big = run_case(&mut rep, &fx_big, RecoverySolverKind::Iterative);
    println!(
        "peaks: cholesky {} KiB → {} KiB (4× I), iterative {} KiB → {} KiB (16× I)",
        chol_small.peak_bytes >> 10,
        chol_mid.peak_bytes >> 10,
        iter_small.peak_bytes >> 10,
        iter_big.peak_bytes >> 10,
    );
    assert!(
        iter_big.peak_bytes <= 32 * iter_small.peak_bytes,
        "CGNR peak must be linear in I, not quadratic: {} → {} bytes across 16× I",
        iter_small.peak_bytes,
        iter_big.peak_bytes
    );
    let gram_bytes = i_big * i_big * 4;
    assert!(
        8 * iter_big.peak_bytes <= gram_bytes,
        "CGNR peak {} at I={i_big} should be ≪ the {gram_bytes}-byte Gram it avoids",
        iter_big.peak_bytes
    );

    rep.finish();
}
