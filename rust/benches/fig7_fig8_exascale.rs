//! Figures 7 & 8 — "exascale" decomposition across a sparsity sweep: time
//! (Fig. 7) and MSE (Fig. 8), baseline vs the optimized compressed-sensing
//! path (§IV-D).
//!
//! The paper sweeps the nonzeros of exascale tensors; we fix the (virtual)
//! size at 240³ and sweep nnz per factor column — the sensing path's
//! advantage (sparse stage-1 maps + one shared first compression) grows as
//! the tensor gets sparser, which is the shape to reproduce.
//!
//! * **baseline** — standard Alg. 2 pipeline, single-threaded.
//! * **sensing**  — two-stage compressed-sensing pipeline on the pool.

use exascale_tensor::bench_harness::{bench_once, speedup, Report};
use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig, SensingConfig};
use exascale_tensor::tensor::SparseLowRankGenerator;

const RANK: usize = 3;

fn main() {
    // `--quick` bounds the sweep for smoke runs (one sparsity, smaller
    // virtual size); the full sweep remains the tracked figure.
    let quick = std::env::args().any(|a| a == "--quick");
    let size: usize = if quick { 120 } else { 240 };
    let sparsities: &[usize] = if quick { &[8] } else { &[8, 16, 32] };
    let mut fig7 = Report::new("fig7_exascale_time", "sensing vs baseline time (sparsity sweep)");
    let mut fig8 = Report::new("fig8_exascale_mse", "sensing vs baseline MSE (sparsity sweep)");

    for &nnz in sparsities {
        let gen = SparseLowRankGenerator::new(size, size, size, RANK, nnz, 3000 + nnz as u64);

        // Baseline: plain pipeline, sequential.
        let cfg = PipelineConfig::builder()
            .reduced_dims(20, 20, 20)
            .rank(RANK)
            .block([60, 60, 60])
            .backend(Backend::RustSequential)
            .als(60, 1e-9)
            .seed(31)
            .build()
            .expect("config");
        let mut pipe = Pipeline::new(cfg);
        let (base_meas, base_result) = bench_once(&format!("nnz={nnz} baseline"), || {
            pipe.run(&gen).expect("baseline")
        });
        println!(
            "nnz={nnz:<3} baseline {:>8.2}s relerr {:.2e}",
            base_meas.mean_s, base_result.diagnostics.rel_error
        );

        // Optimized: compressed sensing + pool.
        let cfg = PipelineConfig::builder()
            .reduced_dims(20, 20, 20)
            .rank(RANK)
            .block([60, 60, 60])
            .backend(Backend::RustParallel)
            .sensing(SensingConfig {
                alpha: 2.2,
                nnz_per_col: 16,
                lambda: 0.02,
            })
            .als(60, 1e-9)
            .seed(31)
            .build()
            .expect("config");
        let mut pipe = Pipeline::new(cfg);
        let (opt_meas, opt_result) = bench_once(&format!("nnz={nnz} sensing"), || {
            pipe.run(&gen).expect("sensing")
        });
        let sp = speedup(base_meas.mean_s, opt_meas.mean_s);
        println!(
            "nnz={nnz:<3} sensing  {:>8.2}s relerr {:.2e} speedup {sp:.2}x",
            opt_meas.mean_s, opt_result.diagnostics.rel_error
        );

        fig7.push(base_meas.clone());
        fig7.push(opt_meas.clone().with_extra("speedup", sp));
        fig8.push(
            base_meas
                .with_extra("mse", base_result.diagnostics.sampled_mse)
                .with_extra("rel_error", base_result.diagnostics.rel_error),
        );
        fig8.push(
            opt_meas
                .with_extra("mse", opt_result.diagnostics.sampled_mse)
                .with_extra("rel_error", opt_result.diagnostics.rel_error),
        );
    }
    fig7.finish();
    fig8.finish();
}
