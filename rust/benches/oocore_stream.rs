//! `BENCH_oocore_stream` — the out-of-core streaming engine end to end:
//!
//! 1. **Author** a file-backed tensor with the streamed writer (reports
//!    write throughput; the file, not RAM, holds the tensor from here on).
//! 2. **Decompose under budget**: run the full pipeline on the
//!    [`FileTensorSource`] with `memory_budget` strictly below the
//!    tensor's byte size, and **assert** (via the counting global
//!    allocator) that the run's transient heap peak stays under that
//!    budget — the repo's first configuration that genuinely decomposes a
//!    tensor larger than its permitted resident memory.
//! 3. **Prefetch speedup**: stream the compression stage over a
//!    throttled file source (fixed per-block latency calibrated to ~1.5×
//!    the measured per-block compute, modeling cold storage) with and
//!    without the prefetching scheduler, and **assert** the overlap wins
//!    ≥ 1.2× — plus bitwise equality of the proxies across arms.
//!
//! `--quick` bounds sizes for the CI smoke job; failures are hard
//! `assert!`s so regressions fail CI instead of rotting.

use exascale_tensor::bench_harness::{bench_once, speedup, Report};
use exascale_tensor::compress::{
    compress_source_opts, MapSource, MapTier, PrefetchConfig, RustCompressor, StreamOptions,
};
use exascale_tensor::coordinator::{Pipeline, PipelineConfig};
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::tensor::{
    save_tensor_streamed, BlockRange, DenseTensor, FileTensorSource, LowRankGenerator,
    TensorSource,
};
use exascale_tensor::util::alloc::CountingAlloc;
use std::time::Duration;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// File source with a fixed per-block read latency: the cold-NFS /
/// object-storage model for the prefetch arms (block *values* are
/// untouched, so results stay bitwise comparable).
struct ThrottledSource<'a> {
    inner: &'a FileTensorSource,
    delay: Duration,
}

impl TensorSource for ThrottledSource<'_> {
    fn dims(&self) -> [usize; 3] {
        self.inner.dims()
    }

    fn block(&self, r: &BlockRange) -> DenseTensor {
        std::thread::sleep(self.delay);
        self.inner.block(r)
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let size: usize = if quick { 96 } else { 160 };
    let mut rep = Report::new(
        "BENCH_oocore_stream",
        "out-of-core streaming: budgeted pipeline + prefetch overlap",
    );

    let mut path = std::env::temp_dir();
    path.push(format!("exatensor_oocore_bench_{}.ext1", std::process::id()));

    // ── 1. Author the file-backed tensor (streamed slabs) ──
    let bytes = size * size * size * 4;
    {
        let gen = LowRankGenerator::new(size, size, size, 3, 4242);
        let (gen_meas, _) = bench_once("gen_tensor_streamed", || {
            save_tensor_streamed(&gen, &path, 8).expect("streamed write")
        });
        let mibs = (bytes >> 20) as f64 / gen_meas.mean_s.max(1e-9);
        println!(
            "authored {size}³ file tensor: {} MiB in {:.2}s ({mibs:.0} MiB/s)",
            bytes >> 20,
            gen_meas.mean_s
        );
        rep.push(gen_meas.with_extra("mib_per_s", mibs));
    }

    // ── 2. Full pipeline under a budget below the tensor's own size ──
    let src = FileTensorSource::open(&path).expect("open file tensor");
    let budget = bytes * 7 / 10;
    let cfg = PipelineConfig::builder()
        .reduced_dims(16, 16, 16)
        .rank(3)
        .als(60, 1e-9)
        .threads(2)
        .memory_budget(budget)
        .seed(7)
        .build()
        .expect("config");
    let mut pipe = Pipeline::new(cfg);
    ALLOC.reset_peak();
    let live_before = ALLOC.live_bytes();
    let (run_meas, res) = bench_once("oocore_pipeline_budgeted", || {
        pipe.run(&src).expect("budgeted out-of-core run")
    });
    let transient_peak = ALLOC.peak_bytes().saturating_sub(live_before);
    println!(
        "budgeted pipeline: {:.2}s, rel err {:.2e}, plan block {:?} depth {} \
         (budget {} KiB, transient heap peak {} KiB)",
        run_meas.mean_s,
        res.diagnostics.rel_error,
        res.plan.block,
        res.plan.prefetch_depth,
        budget >> 10,
        transient_peak >> 10
    );
    assert!(res.plan.out_of_core, "budget {budget} < tensor {bytes} must plan out-of-core");
    assert!(
        res.diagnostics.rel_error < 5e-2,
        "out-of-core run lost accuracy: rel {}",
        res.diagnostics.rel_error
    );
    // The memory claim, asserted: streaming a larger-than-budget tensor
    // must not allocate past the budget.
    assert!(
        transient_peak < budget,
        "transient heap peak {transient_peak} B exceeds memory budget {budget} B"
    );
    rep.push(
        run_meas
            .with_extra("rel_error", res.diagnostics.rel_error)
            .with_extra("alloc_peak_bytes", transient_peak as f64)
            .with_extra("budget_bytes", budget as f64),
    );

    // ── 3. Prefetch overlap on a latency-bound source ──
    let maps = MapSource::generate([size, size, size], [16, 16, 16], 4, 2, 99, MapTier::Materialized);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let block = [32, 32, 32];
    let threads = 2;

    // Calibrate the synthetic latency to ~1.5× the measured per-block cost
    // (read + compute), so I/O genuinely contends with compute on any
    // machine this runs on.
    let (calib, baseline_proxies) = bench_once("stream_file_sync", || {
        compress_source_opts(
            &src,
            &maps,
            block,
            &comp,
            &StreamOptions { threads, ..Default::default() },
            None,
            None,
        )
    });
    let nblocks = baseline_proxies.1.blocks_read.max(1);
    let per_block = calib.mean_s * threads as f64 / nblocks as f64;
    let delay = Duration::from_secs_f64((per_block * 1.5).max(0.002));
    println!(
        "calibration: {} blocks, {:.2} ms/block/worker → throttle {:.2} ms",
        nblocks,
        per_block * 1e3,
        delay.as_secs_f64() * 1e3
    );
    let gib_per_s = bytes as f64 / calib.mean_s.max(1e-9) / (1u64 << 30) as f64;
    rep.push(
        calib
            .with_extra("gib_per_s", gib_per_s)
            .with_extra("blocks", nblocks as f64),
    );

    let throttled = ThrottledSource { inner: &src, delay };
    let (sync_meas, sync_out) = bench_once("stream_throttled_sync", || {
        compress_source_opts(
            &throttled,
            &maps,
            block,
            &comp,
            &StreamOptions { threads, ..Default::default() },
            None,
            None,
        )
    });
    let (pref_meas, pref_out) = bench_once("stream_throttled_prefetch", || {
        compress_source_opts(
            &throttled,
            &maps,
            block,
            &comp,
            &StreamOptions {
                threads,
                prefetch: Some(PrefetchConfig { depth: 4, io_threads: 2 }),
                ..Default::default()
            },
            None,
            None,
        )
    });
    let sp = speedup(sync_meas.mean_s, pref_meas.mean_s);
    println!(
        "throttled streaming: sync {:.2}s vs prefetch {:.2}s → {sp:.2}x \
         (compute stalled {:.2}s, backpressure {:.2}s)",
        sync_meas.mean_s,
        pref_meas.mean_s,
        pref_out.1.io_stall_seconds,
        pref_out.1.send_stall_seconds
    );
    assert_eq!(
        sync_out.0, pref_out.0,
        "prefetched proxies must be bitwise identical to synchronous"
    );
    assert_eq!(
        baseline_proxies.0, pref_out.0,
        "throttling must not change values, only timing"
    );
    assert!(
        sp >= 1.2,
        "prefetch speedup {sp:.2}x below the 1.2x floor on a latency-bound source"
    );
    rep.push(sync_meas.with_extra("io_seconds", sync_out.1.io_seconds));
    rep.push(
        pref_meas
            .with_extra("speedup", sp)
            .with_extra("io_stall_s", pref_out.1.io_stall_seconds)
            .with_extra("backpressure_s", pref_out.1.send_stall_seconds),
    );

    rep.finish();
    std::fs::remove_file(&path).ok();
}
