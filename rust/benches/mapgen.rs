//! `BENCH_mapgen` — the replica-map path's memory trajectory across tiers.
//!
//! Walks the exact map access pattern of the compression stage — per-block
//! column panels of `U_p`/`V_p`/`W_p` on the trait path plus the stacked
//! `[U_1; …; U_P]` panels of the batched path — at two `I` values **16×
//! apart**, with the counting global allocator bracketing each walk, and
//! **asserts**:
//!
//! 1. the procedural tier's map-path `alloc_peak_bytes` is flat in `I`
//!    (`O(panel)`, not `O(P·L·I)`) — the exascale claim of ISSUE 5;
//! 2. the materialized tier's peak grows ≈ linearly with `I` (the term the
//!    procedural tier eliminates), so the comparison stays honest;
//! 3. both tiers emit bitwise-identical panel streams (checksum equality).
//!
//! `--quick` bounds sizes for the CI smoke job; failures are hard
//! `assert!`s so a map-path memory regression fails CI instead of rotting.

use exascale_tensor::bench_harness::{bench_once, Report};
use exascale_tensor::compress::{MapSource, MapTier};
use exascale_tensor::util::alloc::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Fixed shapes: `P` and the reduced dims are pinned (not planner-derived)
/// so the materialized map bytes scale *linearly* in `I` and the contrast
/// between tiers is attributable to `I` alone.
const P: usize = 8;
const L: usize = 32;
const JK: usize = 64;
const PANEL: usize = 64;

/// Streams every mode-0 panel a compression pass would cut — per-replica
/// and stacked — through one recycled scratch buffer, folding a checksum
/// so generation cannot be optimized away.  Returns (checksum, entries).
fn walk_map_path(maps: &MapSource, full_checksum: bool) -> (f64, u64) {
    let [i, _, _] = maps.dims();
    let mut buf = Vec::new();
    let mut sum = 0.0f64;
    let mut entries = 0u64;
    for p in 0..maps.p_count() {
        let mut c0 = 0;
        while c0 < i {
            let c1 = (c0 + PANEL).min(i);
            let pan = maps.panel(p, 0, c0, c1, std::mem::take(&mut buf));
            let take = if full_checksum { pan.data().len() } else { 8 };
            sum += pan.data().iter().take(take).map(|&x| x as f64).sum::<f64>();
            entries += pan.data().len() as u64;
            buf = pan.into_vec();
            c0 = c1;
        }
    }
    let mut c0 = 0;
    while c0 < i {
        let c1 = (c0 + PANEL).min(i);
        let pan = maps.stacked_panel(0, c0, c1, std::mem::take(&mut buf));
        let take = if full_checksum { pan.data().len() } else { 8 };
        sum += pan.data().iter().take(take).map(|&x| x as f64).sum::<f64>();
        entries += pan.data().len() as u64;
        buf = pan.into_vec();
        c0 = c1;
    }
    (sum, entries)
}

struct Case {
    peak_bytes: usize,
    checksum: f64,
}

fn run_case(rep: &mut Report, tier: MapTier, i_dim: usize, full_checksum: bool) -> Case {
    ALLOC.reset_peak();
    let live0 = ALLOC.live_bytes();
    // Construction is part of the map path: the materialized tier pays its
    // `P×(L·I + M·J + N·K)` storage here, the procedural tier only a spec.
    let maps = MapSource::generate([i_dim, JK, JK], [L, L, L], P, 4, 42, tier);
    let name = format!("mapgen_{}_{i_dim}", tier.as_str());
    let (meas, (checksum, entries)) =
        bench_once(&name, || walk_map_path(&maps, full_checksum));
    let peak_bytes = ALLOC.peak_bytes().saturating_sub(live0);
    let entries_per_s = entries as f64 / meas.mean_s.max(1e-9);
    println!(
        "{name}: peak {} KiB, {:.1} M entries/s",
        peak_bytes >> 10,
        entries_per_s / 1e6
    );
    rep.push(
        meas.with_extra("alloc_peak_bytes", peak_bytes as f64)
            .with_extra("entries_per_s", entries_per_s)
            .with_extra("i_dim", i_dim as f64),
    );
    Case { peak_bytes, checksum }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let i_small: usize = if quick { 1 << 10 } else { 1 << 12 };
    let i_big = 16 * i_small;
    let mut rep = Report::new(
        "BENCH_mapgen",
        "replica-map path: procedural alloc peak flat across 16x I",
    );

    // Small I with full checksums: the tiers must emit identical streams.
    let mat_small = run_case(&mut rep, MapTier::Materialized, i_small, true);
    let proc_small = run_case(&mut rep, MapTier::Procedural, i_small, true);
    assert_eq!(
        mat_small.checksum.to_bits(),
        proc_small.checksum.to_bits(),
        "tiers must stream bitwise-identical panels"
    );

    // 16× I: the procedural peak must stay flat, the materialized must not.
    let mat_big = run_case(&mut rep, MapTier::Materialized, i_big, false);
    let proc_big = run_case(&mut rep, MapTier::Procedural, i_big, false);
    println!(
        "peaks: materialized {} KiB → {} KiB ({}×), procedural {} KiB → {} KiB",
        mat_small.peak_bytes >> 10,
        mat_big.peak_bytes >> 10,
        mat_big.peak_bytes / mat_small.peak_bytes.max(1),
        proc_small.peak_bytes >> 10,
        proc_big.peak_bytes >> 10,
    );
    assert!(
        proc_big.peak_bytes * 2 <= proc_small.peak_bytes * 3,
        "procedural map-path peak must be flat in I: {} → {} bytes across 16× I",
        proc_small.peak_bytes,
        proc_big.peak_bytes
    );
    assert!(
        mat_big.peak_bytes >= 8 * mat_small.peak_bytes,
        "materialized peak should scale ~linearly with I ({} → {}); \
         if this broke, the contrast baseline is wrong",
        mat_small.peak_bytes,
        mat_big.peak_bytes
    );
    assert!(
        16 * proc_big.peak_bytes <= mat_big.peak_bytes,
        "procedural peak {} must be ≪ materialized {} at I={i_big}",
        proc_big.peak_bytes,
        mat_big.peak_bytes
    );

    rep.finish();
}
