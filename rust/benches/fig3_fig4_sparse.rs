//! Figures 3 & 4 — sparse tensor decomposition: time (Fig. 3) and MSE
//! (Fig. 4), CPU baseline vs the GPU-tensor-core arm.
//!
//! Paper setting: nnz per mode column = 100, compression ratio 10
//! (`L = I/10`). Scaled sweep: `I ∈ {100, 200, 400}` with nnz/col = I/10.
//!
//! * **baseline (dense-als)** — conventional-toolbox behaviour: direct
//!   dense ALS on the materialized tensor.  At I=400 this needs 3 dense
//!   unfoldings of a 64M-element tensor (~768 MB): it is *memory-gated*,
//!   exactly the paper's point — reported as DNF.
//! * **compressed(xla)** — the compressed pipeline on the AOT artifacts
//!   (ratio-10 proxies).
//! * **sparse-als** — informational: our sparse direct ALS (what a
//!   sparsity-aware baseline achieves).

use exascale_tensor::bench_harness::{bench_once, speedup, Report};
use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig};
use exascale_tensor::cp::{als_decompose, als_decompose_sparse, AlsOptions};
use exascale_tensor::runtime::{artifacts_dir, XlaBackend, XlaRuntime};
use exascale_tensor::tensor::{DenseTensor, SparseLowRankGenerator, SparseTensor};

const RANK: usize = 3;
const BLOCK: usize = 50;

fn main() {
    let sizes = [100usize, 200, 400];
    let rt = XlaRuntime::load(artifacts_dir(), 2).ok();
    if rt.is_none() {
        eprintln!("WARNING: artifacts missing; xla arm skipped (run `make artifacts`)");
    }
    let mut fig3 = Report::new("fig3_sparse_time", "sparse decomposition time");
    let mut fig4 = Report::new("fig4_sparse_mse", "sparse reconstruction MSE");

    for &size in &sizes {
        let nnz_per_col = size / 10;
        let gen = SparseLowRankGenerator::new(size, size, size, RANK, nnz_per_col, 2000 + size as u64);
        let (a, b, c) = gen.factors().clone();

        // ---- baseline: dense direct ALS (memory-gated at 400³) ----
        let mut base_time = None;
        if size <= 200 {
            let dense = DenseTensor::from_cp_factors(&a, &b, &c);
            let (meas, out) = bench_once(&format!("I={size} baseline(dense-als)"), || {
                als_decompose(
                    &dense,
                    &AlsOptions {
                        rank: RANK,
                        max_iters: 60,
                        tol: 1e-9,
                        seed: 3,
                        ..Default::default()
                    },
                )
                .expect("dense als")
            });
            let (model, _) = out;
            let err = model.to_tensor().rel_error(&dense);
            let mse = err * err * (dense.frobenius_norm().powi(2)) / dense.len() as f64;
            println!("I={size:<4} baseline(dense-als)   {:>8.2}s relerr {err:.2e}", meas.mean_s);
            base_time = Some(meas.mean_s);
            fig3.push(meas.clone());
            fig4.push(meas.with_extra("mse", mse).with_extra("rel_error", err));
        } else {
            println!(
                "I={size:<4} baseline(dense-als)   DNF (≈{} MB dense working set — memory-gated, as in the paper)",
                size * size * size * 4 * 3 / (1024 * 1024)
            );
        }

        // ---- compressed pipeline on XLA artifacts ----
        if let Some(rt) = rt.as_ref() {
            let l = size / 10;
            let cfg = PipelineConfig::builder()
                .reduced_dims(l, l, l)
                .rank(RANK)
                .block([BLOCK, BLOCK, BLOCK])
                .backend(Backend::Xla)
                .als(60, 1e-9)
                .seed(23)
                .build()
                .expect("config");
            let mut pipe = Pipeline::new(cfg).with_compute(std::sync::Arc::new(
                XlaBackend::new(rt.clone(), [l, l, l], BLOCK, RANK, 60, 1e-9, 4)
                    .expect("xla backend artifacts"),
            ));
            let (meas, result) =
                bench_once(&format!("I={size} compressed(xla)"), || {
                    pipe.run(&gen).expect("pipeline")
                });
            let sp = base_time.map(|b| speedup(b, meas.mean_s)).unwrap_or(f64::NAN);
            println!(
                "I={size:<4} compressed(xla)       {:>8.2}s relerr {:.2e} speedup {sp:.2}x",
                meas.mean_s, result.diagnostics.rel_error
            );
            fig3.push(meas.clone().with_extra("speedup", sp));
            fig4.push(
                meas.with_extra("mse", result.diagnostics.sampled_mse)
                    .with_extra("rel_error", result.diagnostics.rel_error),
            );
        }

        // ---- informational: sparsity-aware direct ALS ----
        // COO built straight from the sparse factors (no densification).
        let coo = SparseTensor::from_sparse_factors(&a, &b, &c);
        let (meas, out) = bench_once(&format!("I={size} sparse-als"), || {
            als_decompose_sparse(
                &coo,
                &AlsOptions {
                    rank: RANK,
                    max_iters: 60,
                    tol: 1e-9,
                    seed: 4,
                    ..Default::default()
                },
            )
            .expect("sparse als")
        });
        let (model, _) = out;
        let resid = coo.residual_sq(&model.a, &model.b, &model.c).sqrt();
        let err = resid / coo.frobenius_norm().max(1e-300);
        println!("I={size:<4} sparse-als (info)     {:>8.2}s relerr {err:.2e}", meas.mean_s);
        fig3.push(meas.clone());
        fig4.push(meas.with_extra("rel_error", err));
    }
    fig3.finish();
    fig4.finish();
}
