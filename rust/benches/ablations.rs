//! Ablations over the design choices DESIGN.md calls out:
//!
//! * anchor rows `S`       — alignment quality vs S (Alg. 2 line 1);
//! * replica count `P`     — recovery error vs the `(I−S)/(L−S)` bound;
//! * mixed precision       — error cost of §IV-B on/naive/off;
//! * block size `d`        — compression throughput vs block size (Fig. 2).

use exascale_tensor::bench_harness::{bench_once, Report};
use exascale_tensor::compress::{compress_source, MapSource, MapTier, RustCompressor};
use exascale_tensor::coordinator::{MemoryPlanner, Pipeline, PipelineConfig};
use exascale_tensor::cp::{model_congruence, CpModel};
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::tensor::{LowRankGenerator, TensorSource};
use exascale_tensor::util::threadpool::ThreadPool;

const SIZE: usize = 96;
const RANK: usize = 4;

fn run_with(cfg: PipelineConfig, gen: &LowRankGenerator) -> (f64, f64, f64) {
    let mut pipe = Pipeline::new(cfg);
    let (meas, result) = bench_once("run", || pipe.run(gen).expect("run"));
    let (a, b, c) = gen.factors.clone();
    let truth = CpModel::new(a, b, c);
    (
        meas.mean_s,
        result.diagnostics.rel_error,
        model_congruence(&truth, &result.model),
    )
}

fn main() {
    let gen = LowRankGenerator::new(SIZE, SIZE, SIZE, RANK, 777);

    // ── S sweep ──
    let mut rep = Report::new("ablation_anchors", "anchor rows S vs recovery quality");
    for s in [RANK, RANK + 2, RANK + 6] {
        let cfg = PipelineConfig::builder()
            .reduced_dims(16, 16, 16)
            .rank(RANK)
            .anchor_rows(s)
            .block([32, 32, 32])
            .seed(1)
            .build()
            .expect("cfg");
        let (t, err, cong) = run_with(cfg, &gen);
        println!("S={s:<3} time {t:.2}s rel_err {err:.2e} congruence {cong:.4}");
        rep.push(
            exascale_tensor::bench_harness::Measurement {
                name: format!("S={s}"),
                mean_s: t,
                p50_s: t,
                p95_s: t,
                iters: 1,
                extra: vec![("rel_error".into(), err), ("congruence".into(), cong)],
            },
        );
    }
    rep.finish();

    // ── P sweep (relative to the identifiability bound) ──
    let mut rep = Report::new("ablation_replicas", "replica count P vs recovery error");
    let min_p = MemoryPlanner::min_replicas_anchored([SIZE; 3], [16; 3], RANK + 2);
    for p in [min_p, min_p + 2, min_p + 8] {
        let cfg = PipelineConfig::builder()
            .reduced_dims(16, 16, 16)
            .rank(RANK)
            .replicas(p)
            .block([32, 32, 32])
            .seed(2)
            .build()
            .expect("cfg");
        let (t, err, cong) = run_with(cfg, &gen);
        println!("P={p:<3} (min {min_p}) time {t:.2}s rel_err {err:.2e} congruence {cong:.4}");
        rep.push(exascale_tensor::bench_harness::Measurement {
            name: format!("P={p}"),
            mean_s: t,
            p50_s: t,
            p95_s: t,
            iters: 1,
            extra: vec![("rel_error".into(), err), ("congruence".into(), cong)],
        });
    }
    rep.finish();

    // ── mixed precision arms (§IV-B): full f32, compensated bf16 split ──
    let mut rep = Report::new("ablation_mixed", "mixed-precision error cost (§IV-B)");
    for (name, mixed) in [("f32", false), ("bf16-split", true)] {
        let cfg = PipelineConfig::builder()
            .reduced_dims(16, 16, 16)
            .rank(RANK)
            .block([32, 32, 32])
            .mixed_precision(mixed)
            .seed(3)
            .build()
            .expect("cfg");
        let (t, err, cong) = run_with(cfg, &gen);
        println!("{name:<10} time {t:.2}s rel_err {err:.2e} congruence {cong:.4}");
        rep.push(exascale_tensor::bench_harness::Measurement {
            name: name.to_string(),
            mean_s: t,
            p50_s: t,
            p95_s: t,
            iters: 1,
            extra: vec![("rel_error".into(), err), ("congruence".into(), cong)],
        });
    }
    rep.finish();

    // ── CP vs Tucker: reconstruction-per-parameter on the same tensor ──
    let mut rep = Report::new("ablation_cp_vs_tucker", "CP (ours) vs Tucker (HOSVD/HOOI) baseline");
    {
        use exascale_tensor::cp::{hooi, hosvd};
        let small = LowRankGenerator::new(48, 48, 48, RANK, 778).with_noise(1e-3);
        let dense = small.corner(48); // full materialization at this size
        let cfg = PipelineConfig::builder()
            .reduced_dims(12, 12, 12)
            .rank(RANK)
            .block([24, 24, 24])
            .seed(5)
            .build()
            .expect("cfg");
        let mut pipe = Pipeline::new(cfg);
        let (meas, res) = bench_once("cp-compressed", || pipe.run(&small).expect("run"));
        let cp_params = RANK * (48 * 3);
        println!(
            "cp-compressed    {:.2}s rel_err {:.2e} params {cp_params}",
            meas.mean_s, res.diagnostics.rel_error
        );
        rep.push(
            meas.with_extra("rel_error", res.diagnostics.rel_error)
                .with_extra("params", cp_params as f64),
        );
        for (name, ranks, iters) in [("tucker-hosvd", [4usize, 4, 4], 0usize), ("tucker-hooi", [4, 4, 4], 2)] {
            let (meas, model) = bench_once(name, || {
                if iters == 0 {
                    hosvd(&dense, ranks)
                } else {
                    hooi(&dense, ranks, iters).expect("hooi")
                }
            });
            let err = model.to_tensor().rel_error(&dense);
            println!(
                "{name:<16} {:.2}s rel_err {err:.2e} params {}",
                meas.mean_s,
                model.params()
            );
            rep.push(
                meas.with_extra("rel_error", err)
                    .with_extra("params", model.params() as f64),
            );
        }
    }
    rep.finish();

    // ── block size d: compression stage throughput only ──
    let mut rep = Report::new("ablation_blocks", "block size d vs compression throughput");
    let maps = MapSource::generate([SIZE; 3], [16; 3], 8, 6, 4, MapTier::Materialized);
    let pool = ThreadPool::default_sized();
    let comp = RustCompressor {
        precision: MixedPrecision::Full,
    };
    for d in [16usize, 32, 48, 96] {
        let (meas, _) = bench_once(&format!("d={d}"), || {
            compress_source(&gen, &maps, [d, d, d], &comp, &pool)
        });
        let gflops = 3.0 * (SIZE as f64).powi(3) * 16.0 * 8.0 / meas.mean_s / 1e9;
        println!("d={d:<3} compress {:.3}s (~{gflops:.2} GF/s effective)", meas.mean_s);
        rep.push(meas.with_extra("gflops", gflops));
    }
    rep.finish();
}
