//! Figures 5 & 6 — dense tensor decomposition: time (Fig. 5) and MSE
//! (Fig. 6) for the three arms of the paper:
//!
//! * **Baseline**        — the pipeline single-threaded in pure rust;
//! * **Parallel on CPU** — the pipeline on the worker pool (the MPI arm);
//! * **Parallel on GPU** — worker pool + AOT XLA/Pallas artifacts (the
//!   tensor-core arm, MXU-adapted).
//!
//! Sizes are scaled from the paper's 1000–10000 (L=M=N=50) to 96–240
//! (L=M=N=24) so the sweep completes in minutes on CPU-interpret Pallas;
//! the *shape* — parallel ≈ 2×, XLA arm fastest, MSE flat and tiny — is
//! the reproduction target (see EXPERIMENTS.md).

use exascale_tensor::bench_harness::{bench_once, speedup, Report};
use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig};
use exascale_tensor::runtime::{artifacts_dir, XlaBackend, XlaRuntime};
use exascale_tensor::tensor::LowRankGenerator;
use std::sync::Arc;

const RANK: usize = 5;
const REDUCED: usize = 24;
const BLOCK: usize = 60;

fn pipeline(backend: Backend, rt: Option<&XlaRuntime>) -> Pipeline {
    let cfg = PipelineConfig::builder()
        .reduced_dims(REDUCED, REDUCED, REDUCED)
        .rank(RANK)
        .block([BLOCK, BLOCK, BLOCK])
        .backend(backend)
        .als(80, 1e-9)
        .seed(17)
        .build()
        .expect("config");
    let mut pipe = Pipeline::new(cfg);
    if let Some(rt) = rt {
        // One ComputeBackend wires both fused artifacts + CPU kernels.
        let xla = XlaBackend::new(rt.clone(), [REDUCED; 3], BLOCK, RANK, 80, 1e-9, 4)
            .expect("xla backend artifacts");
        pipe = pipe.with_compute(Arc::new(xla));
    }
    pipe
}

fn main() {
    let sizes = [96usize, 144, 192, 240];
    let rt = XlaRuntime::load(artifacts_dir(), 2).ok();
    if rt.is_none() {
        eprintln!("WARNING: artifacts missing; GPU arm will be skipped (run `make artifacts`)");
    }

    let mut fig5 = Report::new("fig5_dense_time", "dense decomposition time by arm");
    let mut fig6 = Report::new("fig6_dense_mse", "dense reconstruction MSE by arm");

    for &size in &sizes {
        let gen = LowRankGenerator::new(size, size, size, RANK, 1000 + size as u64);
        let mut arms: Vec<(&str, Backend, Option<&XlaRuntime>)> = vec![
            ("baseline", Backend::RustSequential, None),
            ("parallel-cpu", Backend::RustParallel, None),
        ];
        if let Some(rt) = rt.as_ref() {
            arms.push(("parallel-gpu(xla)", Backend::Xla, Some(rt)));
        }
        let mut base_time = None;
        for (name, backend, rt) in arms {
            let mut pipe = pipeline(backend, rt);
            let label = format!("I={size} {name}");
            let (meas, result) = bench_once(&label, || pipe.run(&gen).expect("run"));
            let t = meas.mean_s;
            if name == "baseline" {
                base_time = Some(t);
            }
            let sp = base_time.map(|b| speedup(b, t)).unwrap_or(1.0);
            println!(
                "{label:<28} {t:>8.2}s  speedup {sp:>5.2}x  relerr {:.2e}",
                result.diagnostics.rel_error
            );
            fig5.push(meas.clone().with_extra("speedup", sp));
            fig6.push(meas.with_extra("mse", result.diagnostics.sampled_mse).with_extra(
                "rel_error",
                result.diagnostics.rel_error,
            ));
        }
    }
    fig5.finish();
    fig6.finish();
}
