//! Gene analysis (§V-C second application): relative error + wall-clock of
//! the compressed decomposition of the synthetic individual×tissue×gene
//! tensor, at two scales.

use exascale_tensor::apps::{run_gene_analysis, GeneConfig};
use exascale_tensor::bench_harness::{Measurement, Report};

fn main() {
    let mut report = Report::new("gene_analysis", "gene tensor decomposition (§V-C)");
    let cases = [
        ("small", GeneConfig {
            individuals: 60,
            tissues: 16,
            genes: 200,
            programs: 3,
            ..Default::default()
        }),
        ("default", GeneConfig::default()),
    ];
    for (name, cfg) in cases {
        let r = run_gene_analysis(&cfg).expect("gene analysis");
        println!(
            "{name:<8} dims {:?} P={} rel_err {:.3}% congruence {:.4} time {:.2}s",
            r.dims,
            r.replicas,
            100.0 * r.rel_error,
            r.factor_congruence,
            r.decompose_seconds
        );
        report.push(Measurement {
            name: format!("{name} {:?}", r.dims),
            mean_s: r.decompose_seconds,
            p50_s: r.decompose_seconds,
            p95_s: r.decompose_seconds,
            iters: 1,
            extra: vec![
                ("rel_error_pct".into(), 100.0 * r.rel_error),
                ("congruence".into(), r.factor_congruence),
                ("replicas".into(), r.replicas as f64),
            ],
        });
    }
    report.finish();
}
