//! `BENCH_batch_lane` — throughput of the scheduler's batched small-tensor
//! fast lane versus the per-job solo path.
//!
//! Floods one scheduler with many small, sweep-compatible jobs twice:
//!
//! * **solo** arm — batch lane disabled (`batch_threshold_bytes = 0`), so
//!   every job runs the ordinary one-job-per-worker path;
//! * **batch** arm — lane enabled with an unlimited threshold, so queued
//!   compatible jobs coalesce into shared fused-ALS sweeps.
//!
//! Both arms use one worker and submit the flood behind a high-priority
//! blocker job so the queue is deep when the first lane tick fires.  The
//! bench **asserts**:
//!
//! 1. every job's `model_digest` is bitwise identical across the two arms
//!    (the lane's core guarantee — coalescing must not change results);
//! 2. the batch arm actually coalesced (`batch_jobs_coalesced > 0`) and
//!    the solo arm never did (`batch_sweeps == 0`);
//! 3. in full mode, the 256-job flood finishes at least **2×** faster
//!    through the lane.
//!
//! `--quick` shrinks the flood for the CI smoke job; the identity and
//! coalescing asserts still run so a silent lane regression fails CI.

use std::sync::Arc;
use std::time::Duration;

use exascale_tensor::bench_harness::{bench_once, speedup, Report};
use exascale_tensor::coordinator::{Metrics, PipelineConfig};
use exascale_tensor::serve::{JobSource, JobSpec, Scheduler, SchedulerConfig, Spool};

/// One small, lane-eligible job.  `threads(1)` is the realistic tenant
/// posture this lane exists for: a tiny job cannot profitably go wide on
/// its own, so the solo arm runs it serially while the shared sweep packs
/// every job's replicas onto the host's full width.  `als_tol = 0` pins
/// every job to the full iteration budget so the measured work is
/// identical across arms and runs (no data-dependent early convergence).
fn small_spec(seed: u64, tenant: &str) -> JobSpec {
    JobSpec {
        source: JobSource::Synthetic { size: 20, rank: 2, noise: 0.0, seed },
        config: PipelineConfig::builder()
            .reduced_dims(10, 10, 10)
            .rank(2)
            .anchor_rows(4)
            .block([10, 10, 10])
            .als(320, 0.0)
            .threads(1)
            .seed(seed)
            .build()
            .unwrap(),
        priority: 0,
        tenant: tenant.to_string(),
        sharded: false,
        no_cache: false,
    }
}

/// High-priority job that occupies the lone worker while the flood is
/// being submitted, so both arms admit from an equally deep queue.
fn blocker_spec(iters: usize) -> JobSpec {
    JobSpec {
        source: JobSource::Synthetic { size: 40, rank: 2, noise: 0.0, seed: 7 },
        config: PipelineConfig::builder()
            .reduced_dims(12, 12, 12)
            .rank(2)
            .anchor_rows(4)
            .block([12, 12, 12])
            .als(iters, 1e-12)
            .threads(2)
            .seed(7)
            .build()
            .unwrap(),
        priority: 10,
        tenant: String::new(),
        sharded: false,
        no_cache: false,
    }
}

struct ArmResult {
    digests: Vec<u64>,
    sweeps: u64,
    coalesced: u64,
}

/// Runs one full flood through a fresh scheduler and returns every job's
/// digest (in submission order) plus the lane counters.
fn run_arm(dir: &std::path::Path, lane_on: bool, jobs: usize, blocker_iters: usize) -> ArmResult {
    let cfg = SchedulerConfig {
        workers: 1,
        batch_threshold_bytes: if lane_on { usize::MAX } else { 0 },
        batch_max_jobs: jobs.max(2),
        ..Default::default()
    };
    let metrics = Arc::new(Metrics::new());
    let s = Scheduler::new(Spool::open(dir).unwrap(), cfg, metrics).unwrap();

    // Park the worker on the blocker, then pile up the flood behind it.
    let blocker = s.submit(blocker_spec(blocker_iters)).unwrap();
    while matches!(
        s.status(&blocker.id).unwrap().state,
        exascale_tensor::serve::JobState::Submitted | exascale_tensor::serve::JobState::Queued
    ) {
        std::thread::sleep(Duration::from_millis(1));
    }
    let ids: Vec<String> = (0..jobs)
        .map(|i| {
            let tenant = if i % 2 == 0 { "acme" } else { "beta" };
            s.submit(small_spec(1000 + i as u64, tenant)).unwrap().id
        })
        .collect();

    let mut digests = Vec::with_capacity(jobs);
    for id in &ids {
        let rec = s.wait(id, Duration::from_secs(600)).unwrap();
        assert_eq!(
            rec.state,
            exascale_tensor::serve::JobState::Done,
            "job {id} ended {:?} ({:?})",
            rec.state,
            rec.error
        );
        let out = rec.outcome.expect("done job has an outcome");
        assert!(!out.from_cache, "flood seeds are distinct; no job may hit the cache");
        digests.push(out.model_digest);
    }
    let sweeps = s.metrics().counter("batch_sweeps");
    let coalesced = s.metrics().counter("batch_jobs_coalesced");
    s.shutdown();
    s.join();
    ArmResult { digests, sweeps, coalesced }
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bench_batch_lane_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let jobs = if quick { 24 } else { 256 };
    let blocker_iters = if quick { 200 } else { 600 };
    let mut rep = Report::new(
        "BENCH_batch_lane",
        "Batched small-tensor lane: coalesced fused-ALS sweeps vs solo runs",
    );

    println!("flood: {jobs} jobs ({})", if quick { "quick" } else { "full" });

    let solo_dir = tmpdir("solo");
    let (solo_m, solo) = bench_once("solo_flood", || run_arm(&solo_dir, false, jobs, blocker_iters));
    assert_eq!(solo.sweeps, 0, "lane disabled must never sweep");
    let solo_s = solo_m.mean_s;
    println!("  solo  : {solo_s:>8.3} s");
    rep.push(solo_m.with_extra("jobs", jobs as f64));

    let batch_dir = tmpdir("batch");
    let (batch_m, batch) = bench_once("batch_flood", || run_arm(&batch_dir, true, jobs, blocker_iters));
    let batch_s = batch_m.mean_s;
    println!(
        "  batch : {batch_s:>8.3} s  ({} sweeps, {} jobs coalesced)",
        batch.sweeps, batch.coalesced
    );
    rep.push(
        batch_m
            .with_extra("jobs", jobs as f64)
            .with_extra("batch_sweeps", batch.sweeps as f64)
            .with_extra("batch_jobs_coalesced", batch.coalesced as f64),
    );

    // The lane's two contracts: it must actually coalesce, and coalescing
    // must be invisible in the results.
    assert!(
        batch.coalesced > 0,
        "lane enabled with a deep queue of compatible jobs but nothing coalesced"
    );
    assert!(batch.sweeps >= 1, "coalesced jobs must be counted in batch_sweeps");
    assert_eq!(solo.digests.len(), batch.digests.len());
    for (i, (s_d, b_d)) in solo.digests.iter().zip(&batch.digests).enumerate() {
        assert_eq!(
            s_d, b_d,
            "job {i}: batched digest {b_d:016x} != solo digest {s_d:016x} — \
             the lane broke bitwise identity"
        );
    }
    println!("  digests: {} jobs bitwise identical across arms", solo.digests.len());

    let sp = speedup(solo_s, batch_s);
    println!("  speedup: {sp:.2}x");
    if !quick {
        assert!(
            sp >= 2.0,
            "batch lane speedup {sp:.2}x < 2x on the {jobs}-job flood"
        );
    }

    std::fs::remove_dir_all(&solo_dir).ok();
    std::fs::remove_dir_all(&batch_dir).ok();
    rep.finish();
}
