//! Table I — CP tensor layer on the CNN: classification accuracy after
//! fine-tuning and decomposition time for the three CP backends
//! (Matlab-style hosvd-ALS, TensorLy-style random-ALS, ours).

use exascale_tensor::apps::nn::{train, Network, SyntheticImages, TrainConfig};
use exascale_tensor::apps::{run_cp_layer_experiment, CpBackend};
use exascale_tensor::bench_harness::Report;
use exascale_tensor::bench_harness::Measurement;

fn clone_net(reference: &Network, seed: u64) -> Network {
    let mut net = Network::new(18, 8, 16, 32, 3, seed);
    net.conv1.weight = reference.conv1.weight.clone();
    net.conv1.bias = reference.conv1.bias.clone();
    net.conv2.weight = reference.conv2.weight.clone();
    net.conv2.bias = reference.conv2.bias.clone();
    net.fc1.weight = reference.fc1.weight.clone();
    net.fc1.bias = reference.fc1.bias.clone();
    net.fc2.weight = reference.fc2.weight.clone();
    net.fc2.bias = reference.fc2.bias.clone();
    net
}

fn main() {
    let seed = 42u64;
    let gen = SyntheticImages::default();
    let train_ds = gen.generate(240, 1);
    let test_ds = gen.generate(90, 2);

    println!("training reference CNN…");
    let mut reference = Network::new(18, 8, 16, 32, 3, seed);
    train(&mut reference, &train_ds, &TrainConfig { epochs: 3, lr: 0.01, seed });

    let mut table = Report::new("table1_cp_layer", "Table I: CP tensor layer accuracy/time");
    println!(
        "{:<26} {:>8} {:>9} {:>9} {:>8} {:>8}",
        "method", "acc pre", "acc drop", "acc ft", "time(s)", "rel err"
    );
    for backend in [CpBackend::Hosvd, CpBackend::Random, CpBackend::Compressed] {
        let mut net = clone_net(&reference, seed);
        let r = run_cp_layer_experiment(&mut net, &train_ds, &test_ds, 8, backend, 1, seed)
            .expect("cp layer experiment");
        println!(
            "{:<26} {:>7.1}% {:>8.1}% {:>8.1}% {:>8.2} {:>8.4}",
            r.backend,
            100.0 * r.accuracy_before,
            100.0 * r.accuracy_after_decomp,
            100.0 * r.accuracy_after_finetune,
            r.decomp_seconds,
            r.reconstruction_error
        );
        let m = Measurement {
            name: r.backend.to_string(),
            mean_s: r.decomp_seconds,
            p50_s: r.decomp_seconds,
            p95_s: r.decomp_seconds,
            iters: 1,
            extra: vec![
                ("accuracy_pct".into(), 100.0 * r.accuracy_after_finetune),
                ("acc_after_decomp_pct".into(), 100.0 * r.accuracy_after_decomp),
                ("reconstruction_error".into(), r.reconstruction_error),
                ("compression_ratio".into(), r.compression_ratio),
            ],
        };
        table.push(m);
    }
    table.finish();
}
