//! Integration tests for the content-addressed artifact store: a rank
//! sweep over one source runs Stage 1 exactly once (the proxy key
//! deliberately excludes rank), reused runs are bitwise identical to
//! cold ones, `no_cache` bypasses both the result cache and the store,
//! and artifacts survive a daemon restart because the store lives in
//! the spool.

use exascale_tensor::coordinator::PipelineConfig;
use exascale_tensor::serve::{
    protocol, JobRecord, JobSource, JobSpec, JobState, Request, SchedulerConfig, Server,
    ServerConfig,
};
use std::time::{Duration, Instant};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("exatensor_store_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

/// One member of the rank sweep.  Everything the proxy stage key hashes
/// — source, reduced dims, replicas, anchor, map seed, block — is held
/// identical across members; only `rank` (and with it the ALS solve)
/// varies.  The anchor must be pinned explicitly: its default derives
/// from rank, which would silently split the sweep across three keys.
/// Replicas stay unpinned — the planner derives them from dims, reduced
/// and anchor alone, all constant here.
fn sweep_spec(rank: usize, als_iters: usize, no_cache: bool) -> JobSpec {
    JobSpec {
        source: JobSource::Synthetic { size: 24, rank: 2, noise: 0.0, seed: 77 },
        config: PipelineConfig::builder()
            .reduced_dims(8, 8, 8)
            .rank(rank)
            .anchor_rows(6)
            .block([8, 8, 8])
            .als(als_iters, 1e-10)
            .threads(2)
            .seed(7)
            .build()
            .unwrap(),
        priority: 0,
        tenant: String::new(),
        sharded: false,
        no_cache,
    }
}

fn start_server(
    spool: &std::path::Path,
    sched: SchedulerConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind(&ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        spool_dir: spool.to_path_buf(),
        scheduler: sched,
        conn_timeout_ms: 60_000,
        max_conns: 0,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

fn submit(addr: &str, spec: &JobSpec) -> JobRecord {
    let resp = protocol::call_ok(addr, &Request::Submit(spec.clone())).unwrap();
    JobRecord::from_json(resp.get("job").unwrap()).unwrap()
}

fn wait_terminal(addr: &str, id: &str, timeout: Duration) -> JobRecord {
    let start = Instant::now();
    loop {
        let resp = protocol::call_ok(addr, &Request::Status(id.to_string())).unwrap();
        let rec = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
        if rec.state.is_terminal() {
            return rec;
        }
        assert!(start.elapsed() < timeout, "timed out waiting for {id}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn run_to_done(addr: &str, spec: &JobSpec) -> JobRecord {
    let rec = submit(addr, spec);
    let done = wait_terminal(addr, &rec.id, Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "job {}: {:?}", rec.id, done.error);
    done
}

fn metric(addr: &str, key: &str) -> u64 {
    let resp = protocol::call_ok(addr, &Request::Metrics).unwrap();
    resp.get("metrics")
        .and_then(|m| m.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64
}

/// The headline acceptance check: a 3-rank sweep over one source runs
/// Stage 1 once.  Cold truth comes from `no_cache` runs of the same
/// specs on the same daemon (they neither read nor write the store), so
/// every store-reused digest has a storeless twin to match bitwise.
#[test]
fn rank_sweep_runs_stage1_once_and_matches_cold_digests() {
    let dir = tmpdir("sweep");
    let (addr, handle) = start_server(
        &dir,
        SchedulerConfig { cache_bytes: 64 << 20, ..Default::default() },
    );

    // Cold control first: the store must stay untouched.
    let mut cold = std::collections::BTreeMap::new();
    for rank in [2usize, 3, 4] {
        let done = run_to_done(&addr, &sweep_spec(rank, 120, true));
        cold.insert(rank, done.outcome.unwrap().model_digest);
    }
    assert_eq!(metric(&addr, "store_publishes"), 0, "no_cache must not publish");
    assert_eq!(metric(&addr, "store_hits_compress"), 0, "no_cache must not read");
    assert_ne!(cold[&2], cold[&3], "different ranks ⇒ different models");

    // The sweep proper.  Rank 2 streams and publishes; ranks 3 and 4
    // must fetch the resident proxy set instead of streaming.
    let first = run_to_done(&addr, &sweep_spec(2, 120, false));
    assert!(!first.outcome.as_ref().unwrap().from_cache);
    let streamed_after_first = metric(&addr, "blocks_streamed");
    assert!(streamed_after_first > 0, "the first cached run streams");
    assert!(metric(&addr, "store_publishes") >= 1, "stage 1 must be published");

    let mut warm = std::collections::BTreeMap::new();
    warm.insert(2, first.outcome.unwrap().model_digest);
    for rank in [3usize, 4] {
        let done = run_to_done(&addr, &sweep_spec(rank, 120, false));
        let o = done.outcome.unwrap();
        assert!(!o.from_cache, "rank {rank}: stage reuse is not a result-cache hit");
        warm.insert(rank, o.model_digest);
    }
    assert_eq!(
        metric(&addr, "store_hits_compress"),
        2,
        "ranks 3 and 4 must both reuse the rank-2 proxy artifact"
    );
    assert_eq!(
        metric(&addr, "blocks_streamed"),
        streamed_after_first,
        "stage 1 ran once: no block streams after the first sweep member"
    );
    for rank in [2usize, 3, 4] {
        assert_eq!(warm[&rank], cold[&rank], "rank {rank}: reuse must be bitwise invisible");
    }

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// `no_cache` also defeats the result cache: an identical resubmission
/// with the flag recomputes (same digest, fresh run), while one without
/// it is served from the store-backed factor blobs at submit time.
#[test]
fn no_cache_resubmission_recomputes_while_cached_twin_hits() {
    let dir = tmpdir("nocache");
    let (addr, handle) = start_server(
        &dir,
        SchedulerConfig { cache_bytes: 64 << 20, ..Default::default() },
    );

    let first = run_to_done(&addr, &sweep_spec(2, 120, false));
    let digest = first.outcome.unwrap().model_digest;
    let streamed = metric(&addr, "blocks_streamed");

    // Cached twin: terminal at submit, no new work.
    let rec = submit(&addr, &sweep_spec(2, 120, false));
    assert_eq!(rec.state, JobState::Done, "identical resubmission hits the cache");
    let o = rec.outcome.unwrap();
    assert!(o.from_cache);
    assert_eq!(o.model_digest, digest);
    assert_eq!(metric(&addr, "blocks_streamed"), streamed);

    // `no_cache` twin: recomputes end to end — not a cache hit, not a
    // store hit, streams its own blocks — yet lands on the same bits.
    let hits_before = metric(&addr, "store_hits_compress");
    let done = run_to_done(&addr, &sweep_spec(2, 120, true));
    let o = done.outcome.unwrap();
    assert!(!o.from_cache, "no_cache must bypass the result cache");
    assert_eq!(metric(&addr, "store_hits_compress"), hits_before, "and the store");
    assert!(metric(&addr, "blocks_streamed") > streamed, "it streams for itself");
    assert_eq!(o.model_digest, digest, "determinism: same bits either way");

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Artifacts outlive the daemon: a restart on the same spool serves
/// Stage 1 from disk for a job whose *result* was never cached (its ALS
/// budget differs, so its cache key is fresh while its proxy key is
/// shared).  Stage-level reuse is strictly finer than result-level.
#[test]
fn store_survives_daemon_restart_and_outlives_the_result_cache() {
    let dir = tmpdir("restart");
    {
        let (addr, handle) = start_server(
            &dir,
            SchedulerConfig { cache_bytes: 64 << 20, ..Default::default() },
        );
        run_to_done(&addr, &sweep_spec(2, 120, false));
        assert!(metric(&addr, "store_publishes") >= 1);
        protocol::call_ok(&addr, &Request::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    let (addr, handle) = start_server(
        &dir,
        SchedulerConfig { cache_bytes: 64 << 20, ..Default::default() },
    );
    // Fresh registry on the restarted daemon: any streaming would show.
    assert_eq!(metric(&addr, "blocks_streamed"), 0);
    // Same proxy key (ALS iteration cap is not a Stage-1 input), fresh
    // cache key (it *is* a result input): store hit, cache miss.
    let done = run_to_done(&addr, &sweep_spec(3, 110, false));
    let o = done.outcome.unwrap();
    assert!(!o.from_cache);
    assert_eq!(metric(&addr, "store_hits_compress"), 1, "proxies served from disk");
    assert_eq!(metric(&addr, "blocks_streamed"), 0, "no source block ever streamed");
    assert!(o.rel_error < 0.05, "rel {}", o.rel_error);

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
