//! Integration tests for sharded multi-worker execution: a daemon job in
//! `sharded` mode farms leased shard ranges out to worker processes (here,
//! worker *loops* on threads speaking the real TCP protocol) and folds the
//! returned raw accumulators in shard order.  Every test pins the headline
//! guarantee: factors and `model_digest` are **bitwise identical** to a
//! single-process run — through worker fleets, injected worker death and
//! re-leasing, a workerless coordinator draining its own shard grid, and a
//! coordinator restart that resumes the fold from a partial checkpoint.
//!
//! Sharded submissions run with the result cache OFF (`cache_bytes: 0`):
//! `sharded` is execution metadata outside the cache key, so a cached solo
//! twin would otherwise satisfy the submission without exercising the
//! lease protocol at all.

use exascale_tensor::compress::{
    compress_shard_batched, fold_shard_proxies, zero_shard_proxies, MapSource,
    DEFAULT_SHARD_PARTS,
};
use exascale_tensor::coordinator::checkpoint::{self, CompressionProgress};
use exascale_tensor::coordinator::{MemoryPlanner, Pipeline, PipelineConfig};
use exascale_tensor::serve::{
    cache_key, model_digest, protocol, run_worker, JobRecord, JobSource, JobSpec, JobState,
    Request, SchedulerConfig, Server, ServerConfig, Spool, WorkerConfig, WorkerReport,
};
use exascale_tensor::tensor::BlockSpec3;
use exascale_tensor::util::fault::{self, FaultPlan};
use exascale_tensor::util::json::Json;
use exascale_tensor::util::threadpool::ThreadPool;
use std::time::{Duration, Instant};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("exatensor_shardexec_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

/// The deterministic job every test shards (seed varies the input):
/// 24³ at block 8³ → 27 blocks → 27 one-block shards under the fixed
/// [`DEFAULT_SHARD_PARTS`] partition.
fn spec(seed: u64, sharded: bool) -> JobSpec {
    JobSpec {
        source: JobSource::Synthetic { size: 24, rank: 2, noise: 0.0, seed },
        config: PipelineConfig::builder()
            .reduced_dims(8, 8, 8)
            .rank(2)
            .anchor_rows(4)
            .block([8, 8, 8])
            .als(120, 1e-10)
            .threads(2)
            .seed(seed)
            .build()
            .unwrap(),
        priority: 0,
        tenant: String::new(),
        sharded,
        no_cache: false,
    }
}

/// Reference digest: the same job, solo, in-process.
fn solo_digest(seed: u64) -> u64 {
    let s = spec(seed, false);
    let src = s.source.open().unwrap();
    let res = Pipeline::new(s.config).run(src.as_ref()).unwrap();
    model_digest(&res.model)
}

fn sharded_sched(lease_timeout_ms: u64) -> SchedulerConfig {
    SchedulerConfig {
        workers: 1,
        cache_bytes: 0,
        lease_timeout_ms,
        ..Default::default()
    }
}

fn start_server(
    spool: &std::path::Path,
    sched: SchedulerConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind(&ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        spool_dir: spool.to_path_buf(),
        scheduler: sched,
        conn_timeout_ms: 60_000,
        max_conns: 0,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

/// A worker loop on a thread, speaking the daemon's real TCP protocol.
/// Joins with `Err` if the worker "dies" (injected fault) or the drained
/// daemon stops answering — both are expected ends in these tests.
fn spawn_worker(
    addr: &str,
    name: &str,
    fault_key: u64,
) -> std::thread::JoinHandle<anyhow::Result<WorkerReport>> {
    let cfg = WorkerConfig {
        addr: addr.to_string(),
        name: name.to_string(),
        backoff_ms: 25,
        fault_key,
    };
    std::thread::spawn(move || run_worker(&cfg))
}

fn submit(addr: &str, spec: &JobSpec) -> JobRecord {
    let resp = protocol::call_ok(addr, &Request::Submit(spec.clone())).unwrap();
    JobRecord::from_json(resp.get("job").unwrap()).unwrap()
}

fn wait_terminal(addr: &str, id: &str, timeout: Duration) -> JobRecord {
    let start = Instant::now();
    loop {
        let resp = protocol::call_ok(addr, &Request::Status(id.to_string())).unwrap();
        let rec = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
        if rec.state.is_terminal() {
            return rec;
        }
        assert!(start.elapsed() < timeout, "timed out waiting for {id}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn metric(addr: &str, key: &str) -> u64 {
    let resp = protocol::call_ok(addr, &Request::Metrics).unwrap();
    resp.get("metrics")
        .and_then(|m| m.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64
}

fn wait_metric_at_least(addr: &str, key: &str, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while metric(addr, key) < want {
        assert!(Instant::now() < deadline, "{key} never reached {want}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Two live workers serve a sharded job over the real protocol; the
/// digest is bitwise identical to a solo in-process run, the shard-lease
/// counters export through `METRICS`, and `LIST` carries the per-job
/// worker-assignment field.
#[test]
fn two_workers_serve_sharded_job_bitwise_identical_to_solo() {
    let _guard = fault::exclude_faults();
    let dir = tmpdir("two");
    let expected = solo_digest(31);
    let (addr, handle) = start_server(&dir, sharded_sched(5_000));
    let w1 = spawn_worker(&addr, "w1", 0);
    let w2 = spawn_worker(&addr, "w2", 0);
    // Both workers must be registered before the job starts, or the
    // coordinator rightly treats the fleet as absent and self-drains.
    wait_metric_at_least(&addr, "workers_connected", 2);

    let rec = submit(&addr, &spec(31, true));
    let done = wait_terminal(&addr, &rec.id, Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "sharded job failed: {:?}", done.error);
    let o = done.outcome.unwrap();
    assert!(!o.from_cache, "sharded runs must execute, not hit the cache");
    assert_eq!(
        o.model_digest, expected,
        "worker-served sharded run must be bitwise identical to solo"
    );

    // 24³ at block 8³ → 27 shards, every one folded exactly once.
    assert_eq!(metric(&addr, "partials_folded"), 27);
    assert!(metric(&addr, "leases_granted") >= 1);
    assert_eq!(metric(&addr, "workers_connected"), 2);
    assert_eq!(metric(&addr, "leases_relet"), 0, "healthy fleet never re-leases");

    // LIST carries the worker-assignment field (empty once the job's
    // lease ledger is retired, but always present).
    let resp = protocol::call_ok(&addr, &Request::List).unwrap();
    let jobs = match resp.get("jobs") {
        Some(Json::Arr(v)) => v.clone(),
        other => panic!("LIST must return a jobs array, got {other:?}"),
    };
    let mine = jobs
        .iter()
        .find(|j| j.get("id").and_then(|x| x.as_str()) == Some(rec.id.as_str()))
        .expect("sharded job listed");
    assert!(
        matches!(mine.get("workers"), Some(Json::Arr(_))),
        "LIST entries must carry the workers array"
    );

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    // Drained workers exit on their own — via the LEASE shutdown answer
    // or the closed listener; either way the threads end.
    let _ = w1.join().unwrap();
    let _ = w2.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos: a FaultPlan-injected worker death mid-lease.  The flaky worker
/// takes the first lease and dies before its first shard; the deadline
/// sweep re-leases the abandoned range (`leases_relet`), a healthy worker
/// finishes the job, and the digest is still bitwise identical.
#[test]
fn injected_worker_death_releases_lease_and_stays_bitwise() {
    // `key=77` aims the schedule at the flaky worker alone: the
    // scheduler's own worker_panic probes (keyed by job sequence) and the
    // honest worker (key 0) never match.
    let guard = fault::arm_scoped(
        FaultPlan::parse("seed=9;worker_panic:period=1,max=1,key=77").unwrap(),
    );
    let dir = tmpdir("death");
    let expected = solo_digest(47);
    let (addr, handle) = start_server(&dir, sharded_sched(300));
    let flaky = spawn_worker(&addr, "flaky", 77);
    wait_metric_at_least(&addr, "workers_connected", 1);

    let rec = submit(&addr, &spec(47, true));
    // The flaky worker dies on the first shard of its first lease; its
    // thread ending IS the crash the lease deadline exists to absorb.
    let death = flaky.join().unwrap();
    assert!(death.is_err(), "the armed plan must kill the flaky worker");
    assert_eq!(guard.fired(fault::Site::WorkerPanic), 1, "exactly one injected death");

    let honest = spawn_worker(&addr, "honest", 0);
    let done = wait_terminal(&addr, &rec.id, Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "job must survive the death: {:?}", done.error);
    assert_eq!(
        done.outcome.unwrap().model_digest,
        expected,
        "worker death + re-lease must be bitwise invisible"
    );
    assert!(
        metric(&addr, "leases_relet") >= 1,
        "the dead worker's lease must have been re-let"
    );
    assert_eq!(metric(&addr, "partials_folded"), 27);
    assert_eq!(metric(&addr, "workers_connected"), 2);

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    let _ = honest.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// A coordinator with no fleet at all serves the sharded job itself (the
/// self-drain path): same bits, no lease grants.
#[test]
fn workerless_coordinator_self_drains_bitwise_identical() {
    let _guard = fault::exclude_faults();
    let dir = tmpdir("selfdrain");
    let expected = solo_digest(53);
    let (addr, handle) = start_server(&dir, sharded_sched(100));
    let rec = submit(&addr, &spec(53, true));
    let done = wait_terminal(&addr, &rec.id, Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "self-drain failed: {:?}", done.error);
    assert_eq!(
        done.outcome.unwrap().model_digest,
        expected,
        "the workerless coordinator must produce the same bits"
    );
    assert_eq!(metric(&addr, "workers_connected"), 0);
    assert_eq!(metric(&addr, "leases_granted"), 0, "self-drain is not a grant");
    assert_eq!(metric(&addr, "partials_folded"), 27);

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Coordinator restart mid-sharded-job: a daemon "killed" with three
/// shards folded (simulated by authoring its spool record and partial
/// checkpoint) restarts, resumes the fold prefix instead of re-leasing
/// it, and finishes bitwise identical to an uninterrupted run.
#[test]
fn coordinator_restart_resumes_sharded_fold_from_checkpoint() {
    let _guard = fault::exclude_faults();
    let dir = tmpdir("restart");
    let job_spec = spec(61, true);
    let expected = solo_digest(61);

    // Author the killed coordinator's on-disk state: a `running` sharded
    // job record plus a partial checkpoint holding the first 3 shards
    // folded — exactly what the lease ledger checkpoints as it goes.
    let spool = Spool::open(&dir).unwrap();
    let ckpt = spool.checkpoint_dir("job-000001");
    let mut run_cfg = job_spec.config.clone();
    run_cfg.checkpoint_dir = Some(ckpt.clone());
    let dims = job_spec.source.dims().unwrap();
    let plan = MemoryPlanner::plan(&run_cfg, dims).unwrap();
    let maps = MapSource::generate(
        dims,
        run_cfg.reduced,
        plan.replicas,
        run_cfg.effective_anchor(),
        run_cfg.seed,
        plan.map_tier,
    );
    let fp = checkpoint::default_fingerprint(&run_cfg, dims, plan.replicas);
    let blocks_total = BlockSpec3::new(dims, plan.block).num_blocks();
    let shards = ThreadPool::partition(blocks_total, DEFAULT_SHARD_PARTS);
    let src = job_spec.source.open().unwrap();
    let prefix = 3usize;
    let mut folded = zero_shard_proxies(&maps);
    let mut blocks_done = 0usize;
    for &(b0, b1) in &shards[..prefix] {
        let acc = compress_shard_batched(src.as_ref(), &maps, plan.block, b0, b1);
        fold_shard_proxies(&mut folded, acc);
        blocks_done += b1 - b0;
    }
    let progress = CompressionProgress {
        block: plan.block,
        shard_parts: DEFAULT_SHARD_PARTS,
        shards_total: shards.len(),
        shards_done: prefix,
        blocks_done,
        blocks_total,
        path: "batched".to_string(),
        generation: 1,
    };
    checkpoint::save_partial(&ckpt, &fp, &progress, &folded).unwrap();
    let rec = JobRecord {
        id: "job-000001".to_string(),
        seq: 1,
        spec: JobSpec { config: run_cfg, ..job_spec.clone() },
        state: JobState::Running,
        plan_bytes: plan.estimated_bytes,
        cache_key: cache_key(&job_spec).unwrap(),
        cancel_requested: false,
        resolved_solver: None,
        attempts: 0,
        panics: 0,
        error: None,
        outcome: None,
    };
    spool.save(&rec).unwrap();
    drop(spool);

    // "Restart" the coordinator on the crashed spool; no workers connect,
    // so the remaining shards self-drain.
    let (addr, handle) = start_server(&dir, sharded_sched(100));
    assert_eq!(metric(&addr, "jobs_recovered"), 1);
    let done = wait_terminal(&addr, "job-000001", Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "recovered job failed: {:?}", done.error);
    assert_eq!(
        done.outcome.unwrap().model_digest,
        expected,
        "restart mid-sharded-fold must be bitwise invisible"
    );
    // Only the 24 shards beyond the checkpointed prefix were folded after
    // the restart: the prefix was resumed, not recomputed.
    assert_eq!(metric(&addr, "partials_folded"), (shards.len() - prefix) as u64);

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
