//! Developer diagnostics & §Perf probes (all `#[ignore]`d).
//!
//! Run individually with
//! `cargo test --release --test debug_scratch <name> -- --ignored --nocapture`.
//! Each prints stage-by-stage numbers and then panics so the output is
//! always shown; they are measurement tools, not assertions.
use exascale_tensor::compress::{
    compress_source, compress_source_sparse, MapSource, MapTier, ReplicaMaps, RustCompressor,
    SparseSignMatrix,
};
use exascale_tensor::coordinator::recovery::{
    entry_calibrate, normalize_and_align, sensing_recover_mode, stacked_recover,
};
use exascale_tensor::cp::{als_decompose, factor_congruence, AlsOptions, CpModel};
use exascale_tensor::linalg::ista::IstaOptions;
use exascale_tensor::linalg::{matmul, Matrix, Trans};
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::tensor::{InMemorySource, SparseLowRankGenerator, TensorSource};
use exascale_tensor::util::threadpool::ThreadPool;

#[test]
#[ignore]
fn debug_sensing_stages() {
    let gen = SparseLowRankGenerator::new(36, 36, 36, 2, 6, 1004);
    let (a_t, b_t, c_t) = gen.factors().clone();
    let truth = CpModel::new(a_t, b_t, c_t);
    let seed = 7u64;
    let reduced = [12usize, 12, 12];
    let anchor = 5;
    let alpha = 2.2f32;
    let al = ((12.0 * alpha).ceil() as usize).max(13);
    let pool = ThreadPool::new(4);

    let u1 = SparseSignMatrix::generate(al, 36, 10, seed ^ 0x51);
    let v1 = SparseSignMatrix::generate(al, 36, 10, seed ^ 0x52);
    let w1 = SparseSignMatrix::generate(al, 36, 10, seed ^ 0x53);
    let z = compress_source_sparse(&gen, &u1, &v1, &w1, [16, 16, 16], &pool);

    // Exact Z factors.
    let za = u1.mul_dense(&truth.a);
    let zb = v1.mul_dense(&truth.b);
    let zc = w1.mul_dense(&truth.c);
    let z_exact = exascale_tensor::tensor::DenseTensor::from_cp_factors(&za, &zb, &zc);
    eprintln!("Z vs exact: rel {}", z.rel_error(&z_exact));

    let maps2 = MapSource::generate([al, al, al], reduced, 12, anchor, seed ^ 0x54, MapTier::Materialized);
    let z_src = InMemorySource::new(z);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let proxies = compress_source(&z_src, &maps2, [al, al, al], &comp, &pool);
    let mut models = Vec::new();
    for (p, y) in proxies.iter().enumerate() {
        let (m, tr) = als_decompose(
            y,
            &AlsOptions { rank: 2, max_iters: 150, tol: 1e-11, seed: seed ^ p as u64, ..Default::default() },
        )
        .unwrap();
        eprintln!("proxy {p}: fit {:.6}", tr.fits.last().unwrap());
        models.push((p, m));
    }
    let (aligned, kept) = normalize_and_align(models, anchor).unwrap();
    eprintln!("kept {kept:?}");
    let tilde_z = stacked_recover(&aligned, &maps2.subset(&kept)).unwrap();
    eprintln!("tilde_z congA {}", factor_congruence(&za, &tilde_z.a));
    eprintln!("tilde_z congB {}", factor_congruence(&zb, &tilde_z.b));
    eprintln!("tilde_z congC {}", factor_congruence(&zc, &tilde_z.c));

    let ista = IstaOptions { lambda: 0.02, max_iters: 2000, tol: 1e-9 };
    let ra = sensing_recover_mode(&u1, &tilde_z.a, &ista);
    let rb = sensing_recover_mode(&v1, &tilde_z.b, &ista);
    let rc = sensing_recover_mode(&w1, &tilde_z.c, &ista);
    eprintln!("ista congA {}", factor_congruence(&truth.a, &ra));
    eprintln!("ista congB {}", factor_congruence(&truth.b, &rb));
    eprintln!("ista congC {}", factor_congruence(&truth.c, &rc));
    // nnz of recovered columns
    for c in 0..2 {
        let nnz = (0..36).filter(|&i| ra.get(i, c).abs() > 1e-4).count();
        eprintln!("ra col {c} nnz {nnz} (true 6)");
    }

    let tilde = CpModel::new(ra, rb, rc);
    let model = entry_calibrate(&tilde, &gen, 8, seed ^ 0xCA2).unwrap();
    let err = exascale_tensor::cp::sampled_mse(&gen, &model, 8, 16, 1);
    eprintln!("final rel {}", err.rel_error);

    // Compare with an ideal ISTA input (exact compressed factors):
    let ra2 = sensing_recover_mode(&u1, &za, &ista);
    eprintln!("ideal-input ista congA {}", factor_congruence(&truth.a, &ra2));

    let _ = matmul(&Matrix::identity(2), Trans::No, &Matrix::identity(2), Trans::No);
    panic!("debug output above");
}

#[test]
#[ignore]
fn debug_gene_scale() {
    use exascale_tensor::apps::gene::{synthesize, GeneConfig};
    let cfg = GeneConfig {
        individuals: 120, tissues: 30, genes: 800, programs: 5,
        gene_sparsity: 0.05, noise: 0.05, seed: 1, threads: 8,
    };
    let gen = synthesize(&cfg);
    let (_, t, _) = &gen.factors;
    // pairwise cosine of tissue columns
    for i in 0..5 {
        for j in (i+1)..5 {
            let ci = t.col(i); let cj = t.col(j);
            let dot: f32 = ci.iter().zip(cj).map(|(a,b)| a*b).sum();
            let ni: f32 = ci.iter().map(|a| a*a).sum::<f32>().sqrt();
            let nj: f32 = cj.iter().map(|a| a*a).sum::<f32>().sqrt();
            eprintln!("tissue cos({i},{j}) = {:.3}", dot/(ni*nj));
        }
    }
    panic!("see above");
}

#[test]
#[ignore]
fn debug_gene_pipeline_stages() {
    use exascale_tensor::apps::gene::{synthesize, GeneConfig};
    let cfg = GeneConfig {
        individuals: 120, tissues: 30, genes: 800, programs: 5,
        gene_sparsity: 0.05, noise: 0.05, seed: 1, threads: 8,
    };
    let gen = synthesize(&cfg);
    let (ta, tb, tc) = gen.factors.clone();
    let truth = CpModel::new(ta, tb, tc);
    let reduced = [15usize, 15, 40];
    let anchor = 7;
    let p = 30;
    let maps = MapSource::generate([120, 30, 800], reduced, p, anchor, 1 ^ 0x6E6E, MapTier::Materialized);
    let pool = ThreadPool::new(8);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let proxies = compress_source(&gen, &maps, [100, 30, 250], &comp, &pool);
    let mut models = Vec::new();
    for (pi, y) in proxies.iter().enumerate() {
        let (m, tr) = als_decompose(
            y,
            &AlsOptions { rank: 5, max_iters: 120, tol: 1e-10, seed: pi as u64, ..Default::default() },
        ).unwrap();
        if pi < 8 { eprintln!("proxy {pi}: fit {:.5}", tr.fits.last().unwrap()); }
        models.push((pi, m));
    }
    let (aligned, kept) = normalize_and_align(models, anchor).unwrap();
    eprintln!("kept {} of {}", kept.len(), p);
    let tilde = stacked_recover(&aligned, &maps.subset(&kept)).unwrap();
    eprintln!("tilde congA {:.4}", factor_congruence(&truth.a, &tilde.a));
    eprintln!("tilde congB {:.4}", factor_congruence(&truth.b, &tilde.b));
    eprintln!("tilde congC {:.4}", factor_congruence(&truth.c, &tilde.c));
    panic!("see above");
}

#[test]
#[ignore]
fn perf_compress_batched_vs_plain() {
    use exascale_tensor::compress::compress_source_batched;
    use exascale_tensor::tensor::LowRankGenerator;
    use std::time::Instant;
    let gen = LowRankGenerator::new(240, 240, 240, 5, 9000);
    let maps = MapSource::generate([240, 240, 240], [24, 24, 24], 21, 7, 9001, MapTier::Materialized);
    let pool = ThreadPool::new(1);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let t0 = Instant::now();
    let a = compress_source(&gen, &maps, [60, 60, 60], &comp, &pool);
    let plain = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let b = compress_source_batched(&gen, &maps, [60, 60, 60], &pool);
    let batched = t0.elapsed().as_secs_f64();
    eprintln!("plain {plain:.2}s batched {batched:.2}s speedup {:.2}x", plain / batched);
    eprintln!("agreement {}", a[0].rel_error(&b[0]));
    panic!("perf numbers above");
}

#[test]
#[ignore]
fn perf_compress_profile_target() {
    use exascale_tensor::tensor::LowRankGenerator;
    let gen = LowRankGenerator::new(240, 240, 240, 5, 9000);
    let maps = MapSource::generate([240, 240, 240], [24, 24, 24], 21, 7, 9001, MapTier::Materialized);
    let pool = ThreadPool::new(1);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    for _ in 0..2 {
        let _ = compress_source(&gen, &maps, [60, 60, 60], &comp, &pool);
    }
}

#[test]
#[ignore]
fn perf_compress_substages() {
    use exascale_tensor::linalg::{gemm, Trans};
    use exascale_tensor::tensor::{BlockSpec3, LowRankGenerator};
    use std::time::Instant;
    let gen = LowRankGenerator::new(240, 240, 240, 5, 9000);
    let maps = ReplicaMaps::generate([240, 240, 240], [24, 24, 24], 21, 7, 9001);
    let (l, dj, dk) = (24usize, 60usize, 60usize);
    let p_count = 21;
    let u_stack = maps.stacked_u();
    let spec = BlockSpec3::new([240, 240, 240], [60, 60, 60]);
    let (mut t_gen, mut t_m1, mut t_m3, mut t_m2, mut t_slice) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for blk in spec.iter() {
        let t0 = Instant::now();
        let t = gen.block(&blk);
        t_gen += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let u_blk = u_stack.slice_cols(blk.i0, blk.i1);
        let x1 = Matrix::from_vec(60, dj * dk, t.data().to_vec());
        let mut y1_all = Matrix::zeros(p_count * l, dj * dk);
        gemm(1.0, &u_blk, Trans::No, &x1, Trans::No, 0.0, &mut y1_all);
        t_m1 += t0.elapsed().as_secs_f64();

        for (p, rep) in maps.replicas.iter().enumerate() {
            let t0 = Instant::now();
            let y1 = y1_all.slice_rows(p * l, (p + 1) * l);
            let y1_flat = Matrix::from_vec(l * dj, dk, y1.into_vec());
            t_slice += t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let w_blk = rep.w.slice_cols(blk.k0, blk.k1);
            let mut y13 = Matrix::zeros(l * dj, 24);
            gemm(1.0, &y1_flat, Trans::No, &w_blk, Trans::Yes, 0.0, &mut y13);
            t_m3 += t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let v_blk = rep.v.slice_cols(blk.j0, blk.j1);
            for kn in 0..24 {
                let slice = Matrix::from_vec(l, dj, y13.col(kn).to_vec());
                let mut out = Matrix::zeros(l, 24);
                gemm(1.0, &slice, Trans::No, &v_blk, Trans::Yes, 0.0, &mut out);
            }
            t_m2 += t0.elapsed().as_secs_f64();
        }
    }
    eprintln!("gen {t_gen:.2}s mode1 {t_m1:.2}s slice {t_slice:.2}s mode3 {t_m3:.2}s mode2 {t_m2:.2}s");
    panic!("numbers above");
}
