//! Runtime integration: AOT artifacts vs the rust reference, and the full
//! pipeline on the XLA backend.  All tests self-skip (loudly) when
//! `make artifacts` has not been run.

use exascale_tensor::compress::{comp_dense, BlockCompressor};
use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig, ProxyDecomposer};
use exascale_tensor::linalg::Matrix;
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::runtime::{
    artifacts_dir, HostTensor, XlaAlsDecomposer, XlaBackend, XlaCompressor, XlaRuntime,
};
use exascale_tensor::tensor::{DenseTensor, LowRankGenerator};
use exascale_tensor::util::rng::Xoshiro256;

fn runtime(threads: usize) -> Option<XlaRuntime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    // Also self-skip when the crate was built without the `xla` feature
    // (or against the vendored stub): load fails cleanly in that case.
    match XlaRuntime::load(dir, threads) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: xla runtime unavailable ({e})");
            None
        }
    }
}

#[test]
fn every_artifact_compiles_and_runs_zeros() {
    let Some(rt) = runtime(1) else { return };
    // Execute every artifact with zero inputs: must produce outputs of the
    // declared shapes without error (als_sweep hits the ridge path).
    let names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    for name in names {
        let spec = rt.manifest().get(&name).unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|dims| HostTensor::zeros(dims.clone()))
            .collect();
        let out = rt.execute(&name, inputs).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(out.len(), spec.outputs.len(), "{name}");
        for (o, dims) in out.iter().zip(&spec.outputs) {
            assert_eq!(&o.dims, dims, "{name}");
            assert!(o.data.iter().all(|v| v.is_finite()), "{name} produced non-finite");
        }
    }
}

#[test]
fn concurrent_execution_from_many_threads() {
    let Some(rt) = runtime(2) else { return };
    let pool = exascale_tensor::util::threadpool::ThreadPool::new(8);
    let results = pool.map_indexed(32, |i| {
        let x = HostTensor::new(vec![4], vec![i as f32; 4]);
        let y = HostTensor::new(vec![4], vec![1.0; 4]);
        let out = rt.execute("smoke_add", vec![x, y]).expect("execute");
        out[0].data[0]
    });
    for (i, v) in results.into_iter().enumerate() {
        assert_eq!(v, i as f32 + 1.0);
    }
}

#[test]
fn xla_compressor_equals_rust_across_shapes() {
    let Some(rt) = runtime(1) else { return };
    let comp = XlaCompressor::new(rt, [16, 16, 16], 32).expect("artifact");
    let mut rng = Xoshiro256::seed_from_u64(900);
    for (di, dj, dk) in [(32, 32, 32), (32, 16, 8), (5, 32, 19)] {
        let t = DenseTensor::random_normal([di, dj, dk], &mut rng);
        let u = Matrix::random_normal(16, di, &mut rng);
        let v = Matrix::random_normal(16, dj, &mut rng);
        let w = Matrix::random_normal(16, dk, &mut rng);
        let got = comp.compress_block(&t, &u, &v, &w);
        let want = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
        let err = got.rel_error(&want);
        assert!(err < 1e-3, "({di},{dj},{dk}): err {err}");
    }
}

#[test]
fn mixed_artifact_matches_rust_emulation() {
    let Some(rt) = runtime(1) else { return };
    let Ok(spec) = rt.manifest().get("compress_block_l16m16n16_d32_mixed") else {
        eprintln!("SKIP: mixed compress artifact absent");
        return;
    };
    let name = spec.name.clone();
    let mut rng = Xoshiro256::seed_from_u64(901);
    let t = DenseTensor::random_normal([32, 32, 32], &mut rng);
    let u = Matrix::random_normal(16, 32, &mut rng);
    let v = Matrix::random_normal(16, 32, &mut rng);
    let w = Matrix::random_normal(16, 32, &mut rng);
    let out = rt
        .execute(
            &name,
            vec![
                HostTensor::from_tensor(&t),
                HostTensor::from_matrix(&u),
                HostTensor::from_matrix(&v),
                HostTensor::from_matrix(&w),
            ],
        )
        .expect("mixed artifact");
    let got = out[0].to_tensor();
    // Both are *mixed* precision paths; compare against f32 truth with a
    // bf16-sized tolerance, and confirm they're closer to each other.
    let full = comp_dense(&t, &u, &v, &w, MixedPrecision::Full);
    let rust_mixed = comp_dense(&t, &u, &v, &w, MixedPrecision::Bf16);
    assert!(got.rel_error(&full) < 2e-2, "vs full {}", got.rel_error(&full));
    assert!(
        got.rel_error(&rust_mixed) < got.rel_error(&full) * 2.0 + 1e-3,
        "pallas-mixed should track rust-mixed"
    );
}

#[test]
fn xla_als_fit_matches_rust_als() {
    let Some(rt) = runtime(1) else { return };
    let dec = XlaAlsDecomposer::new(rt, [16, 16, 16], 4, 100, 1e-10).expect("artifact");
    let mut rng = Xoshiro256::seed_from_u64(902);
    let a = Matrix::random_normal(16, 4, &mut rng);
    let b = Matrix::random_normal(16, 4, &mut rng);
    let c = Matrix::random_normal(16, 4, &mut rng);
    let y = DenseTensor::from_cp_factors(&a, &b, &c);
    let (model, fit) = dec.decompose(&y, 4, 55).expect("decompose");
    assert!(fit > 0.999, "xla fit {fit}");
    assert!(model.to_tensor().rel_error(&y) < 1e-2);
}

#[test]
fn full_pipeline_on_xla_backend() {
    let Some(rt) = runtime(2) else { return };
    let gen = LowRankGenerator::new(64, 64, 64, 4, 903);
    let cfg = PipelineConfig::builder()
        .reduced_dims(16, 16, 16)
        .rank(4)
        .block([32, 32, 32])
        .backend(Backend::Xla)
        .als(80, 1e-9)
        .seed(12)
        .build()
        .unwrap();
    // The whole XLA arm behind one ComputeBackend constructor.
    let xla = XlaBackend::new(rt, [16, 16, 16], 32, 4, 80, 1e-9, 4).expect("xla backend");
    let mut pipe = Pipeline::new(cfg).with_compute(std::sync::Arc::new(xla));
    let res = pipe.run(&gen).unwrap();
    assert!(
        res.diagnostics.rel_error < 2e-2,
        "xla pipeline rel {}",
        res.diagnostics.rel_error
    );
}

#[test]
fn shape_validation_and_unknown_artifacts() {
    let Some(rt) = runtime(1) else { return };
    assert!(rt.execute("smoke_add", vec![]).is_err());
    let bad = HostTensor::zeros(vec![5]);
    let ok = HostTensor::zeros(vec![4]);
    assert!(rt.execute("smoke_add", vec![bad, ok]).is_err());
    assert!(rt.execute("definitely_not_an_artifact", vec![]).is_err());
}
