//! Property tests on coordinator invariants, run through the in-crate
//! `util::prop` harness (offline substitute for proptest — see DESIGN.md).

use exascale_tensor::compress::{comp_dense, ReplicaMaps};
use exascale_tensor::coordinator::matching::{align_to_reference, anchor_normalize};
use exascale_tensor::coordinator::MemoryPlanner;
use exascale_tensor::cp::CpModel;
use exascale_tensor::linalg::{hungarian_max, hungarian_min, matmul, Matrix, Trans};
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::tensor::unfold::{refold_2, refold_3, unfold_2, unfold_3};
use exascale_tensor::tensor::DenseTensor;
use exascale_tensor::util::prop;
use exascale_tensor::util::rng::Xoshiro256;

#[test]
fn prop_hungarian_max_is_permutation_and_optimal() {
    prop::check("hungarian-max-perm", 40, |g| {
        let n = g.int(1, 6);
        let mut w = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                w.set(i, j, g.f32(-3.0, 3.0));
            }
        }
        let asn = hungarian_max(&w);
        let mut seen = vec![false; n];
        for &c in &asn.col_of_row {
            assert!(!seen[c]);
            seen[c] = true;
        }
        // max == -min of negated matrix
        let neg = Matrix::from_fn(n, n, |i, j| -w.get(i, j));
        let min = hungarian_min(&neg);
        assert!((asn.total + min.total).abs() < 1e-3);
    });
}

#[test]
fn prop_unfold_refold_roundtrip_modes_2_3() {
    prop::check("unfold-roundtrip", 30, |g| {
        let dims = [g.int(1, 6), g.int(1, 6), g.int(1, 6)];
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let t = DenseTensor::random_normal(dims, &mut rng);
        assert_eq!(refold_2(&unfold_2(&t), dims), t);
        assert_eq!(refold_3(&unfold_3(&t), dims), t);
    });
}

#[test]
fn prop_compression_is_linear() {
    prop::check("comp-linear", 20, |g| {
        let d = g.int(2, 6);
        let l = g.int(1, 4);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let t1 = DenseTensor::random_normal([d, d, d], &mut rng);
        let t2 = DenseTensor::random_normal([d, d, d], &mut rng);
        let alpha = g.f32(-2.0, 2.0);
        let u = Matrix::random_normal(l, d, &mut rng);
        let v = Matrix::random_normal(l, d, &mut rng);
        let w = Matrix::random_normal(l, d, &mut rng);
        let combo = DenseTensor::from_fn([d, d, d], |i, j, k| {
            t1.get(i, j, k) + alpha * t2.get(i, j, k)
        });
        let y_combo = comp_dense(&combo, &u, &v, &w, MixedPrecision::Full);
        let y1 = comp_dense(&t1, &u, &v, &w, MixedPrecision::Full);
        let y2 = comp_dense(&t2, &u, &v, &w, MixedPrecision::Full);
        let y_lin = DenseTensor::from_fn([l, l, l], |i, j, k| {
            y1.get(i, j, k) + alpha * y2.get(i, j, k)
        });
        assert!(y_combo.rel_error(&y_lin) < 1e-3, "err {}", y_combo.rel_error(&y_lin));
    });
}

#[test]
fn prop_replica_maps_anchor_invariant() {
    prop::check("maps-anchor", 20, |g| {
        let dim = g.int(8, 20);
        let l = g.int(4, 7);
        let s = g.int(1, l.min(4));
        let p = g.int(2, 5);
        let maps = ReplicaMaps::generate([dim; 3], [l; 3], p, s, g.int(0, 1 << 30) as u64);
        // Anchor rows identical across replicas for all three maps.
        for rep in &maps.replicas[1..] {
            for r in 0..s {
                for c in 0..dim {
                    assert_eq!(rep.u.get(r, c), maps.replicas[0].u.get(r, c));
                    assert_eq!(rep.v.get(r, c), maps.replicas[0].v.get(r, c));
                    assert_eq!(rep.w.get(r, c), maps.replicas[0].w.get(r, c));
                }
            }
        }
        // Stacked shapes.
        assert_eq!(maps.stacked_u().rows(), p * l);
    });
}

#[test]
fn prop_alignment_is_idempotent() {
    prop::check("align-idempotent", 15, |g| {
        let rows = g.int(6, 12);
        let rank = g.int(2, 4);
        let s = rank + 1;
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let mut m = CpModel::new(
            Matrix::random_normal(rows, rank, &mut rng),
            Matrix::random_normal(rows, rank, &mut rng),
            Matrix::random_normal(rows, rank, &mut rng),
        );
        if anchor_normalize(&mut m, s).is_err() {
            return; // degenerate draw: skip
        }
        let (once, rep1) = align_to_reference(&m, &m, s).unwrap();
        let (twice, rep2) = align_to_reference(&m, &once, s).unwrap();
        assert_eq!(rep1.permutation, (0..rank).collect::<Vec<_>>());
        assert_eq!(rep2.permutation, (0..rank).collect::<Vec<_>>());
        assert!(twice.a.rel_error(&once.a) < 1e-6);
    });
}

#[test]
fn prop_planner_bound_monotone_in_anchor() {
    prop::check("planner-anchor-monotone", 30, |g| {
        let dim = g.int(50, 400);
        let l = g.int(8, 30);
        let s1 = g.int(2, l - 2);
        let s2 = g.int(s1, l - 1);
        let p1 = MemoryPlanner::min_replicas_anchored([dim; 3], [l; 3], s1);
        let p2 = MemoryPlanner::min_replicas_anchored([dim; 3], [l; 3], s2);
        // More anchors ⇒ fewer informative rows ⇒ needs ≥ as many replicas.
        assert!(p2 >= p1, "S={s1}→P={p1}, S={s2}→P={p2}");
        // And the bound is actually sufficient: S + P(L−S) ≥ dim.
        if l > s1 {
            assert!(s1 + p1 * (l - s1) >= dim.min(s1 + p1 * (l - s1)));
            assert!(s1 + p1 * (l - s1) >= dim || dim <= l);
        }
    });
}

#[test]
fn prop_mixed_matmul_error_scales_with_precision() {
    prop::check("mixed-precision-order", 15, |g| {
        let n = g.int(4, 24);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let a = Matrix::random_normal(n, n, &mut rng);
        let b = Matrix::random_normal(n, n, &mut rng);
        let exact = matmul(&a, Trans::No, &b, Trans::No);
        let f16 = exascale_tensor::mixed::matmul_mixed(&a, &b, exascale_tensor::mixed::MixedPrecision::F16);
        let bf16 = exascale_tensor::mixed::matmul_mixed(&a, &b, exascale_tensor::mixed::MixedPrecision::Bf16);
        // f16 has 10 mantissa bits vs bf16's 7: compensated f16 ≤ bf16 error
        // (allow slack for tiny matrices).
        let e_f16 = f16.rel_error(&exact);
        let e_bf16 = bf16.rel_error(&exact);
        assert!(e_f16 < e_bf16 * 4.0 + 1e-7, "f16 {e_f16} vs bf16 {e_bf16}");
        assert!(e_bf16 < 1e-3);
    });
}

#[test]
fn prop_cp_model_norm_matches_dense() {
    prop::check("cp-norm", 20, |g| {
        let dims = [g.int(2, 6), g.int(2, 6), g.int(2, 6)];
        let rank = g.int(1, 3);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let m = CpModel::new(
            Matrix::random_normal(dims[0], rank, &mut rng),
            Matrix::random_normal(dims[1], rank, &mut rng),
            Matrix::random_normal(dims[2], rank, &mut rng),
        );
        let dense_sq = m.to_tensor().frobenius_norm().powi(2);
        assert!((m.norm_sq() - dense_sq).abs() / dense_sq.max(1e-9) < 1e-3);
    });
}
