//! Application-level integration: the gene-analysis and CP-layer protocols
//! end to end (scaled down to stay fast in CI).

use exascale_tensor::apps::nn::{evaluate, train, Network, SyntheticImages, TrainConfig};
use exascale_tensor::apps::{run_cp_layer_experiment, run_gene_analysis, CpBackend, GeneConfig};

#[test]
fn gene_analysis_end_to_end() {
    let cfg = GeneConfig {
        individuals: 80,
        tissues: 20,
        genes: 300,
        programs: 4,
        gene_sparsity: 0.08,
        noise: 0.02,
        seed: 9,
        threads: 4,
    };
    let r = run_gene_analysis(&cfg).unwrap();
    assert_eq!(r.dims, [80, 20, 300]);
    assert!(r.rel_error < 0.08, "rel {}", r.rel_error);
    assert!(r.factor_congruence > 0.9, "congruence {}", r.factor_congruence);
    assert!(r.decompose_seconds > 0.0);
}

#[test]
fn cnn_trains_and_cp_layer_protocol_runs() {
    let gen = SyntheticImages::default();
    let train_ds = gen.generate(150, 1);
    let test_ds = gen.generate(60, 2);
    let mut net = Network::new(18, 6, 12, 24, 3, 42);
    train(&mut net, &train_ds, &TrainConfig { epochs: 3, lr: 0.01, seed: 42 });
    let base_acc = evaluate(&mut net, &test_ds);
    assert!(base_acc > 0.8, "base accuracy {base_acc}");

    // Random-ALS backend (cheapest) through the full protocol.
    let r = run_cp_layer_experiment(
        &mut net,
        &train_ds,
        &test_ds,
        8,
        CpBackend::Random,
        1,
        7,
    )
    .unwrap();
    assert!(r.reconstruction_error < 0.85, "recon err {}", r.reconstruction_error); // trained conv tensors are not very low-rank
    // Fine-tuning must not be catastrophically below the pre-compression
    // accuracy at this rank.
    assert!(
        r.accuracy_after_finetune > base_acc - 0.25,
        "tuned {} vs base {base_acc}",
        r.accuracy_after_finetune
    );
}

#[test]
fn cp_layer_compressed_backend_runs() {
    // Exercise OUR pipeline on a real trained conv tensor.
    let gen = SyntheticImages::default();
    let train_ds = gen.generate(120, 3);
    let test_ds = gen.generate(45, 4);
    let mut net = Network::new(18, 6, 12, 24, 3, 44);
    train(&mut net, &train_ds, &TrainConfig { epochs: 2, lr: 0.01, seed: 44 });
    let r = run_cp_layer_experiment(
        &mut net,
        &train_ds,
        &test_ds,
        6,
        CpBackend::Compressed,
        1,
        11,
    )
    .unwrap();
    assert!(r.decomp_seconds > 0.0);
    assert!(r.compression_ratio > 1.0);
    // The compressed pipeline's reconstruction should be finite & sane.
    assert!(r.reconstruction_error.is_finite());
}
