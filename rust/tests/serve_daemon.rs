//! Integration tests for the `serve/` daemon: protocol round trips over a
//! real TCP socket, memory-budget admission queueing, result-cache hits,
//! and crash recovery (spooled jobs + mid-compression checkpoint resume
//! with bitwise-identical output).

use exascale_tensor::compress::{compress_source_batched_opts, MapSource, StreamOptions};
use exascale_tensor::coordinator::checkpoint::{self, CompressionProgress};
use exascale_tensor::coordinator::{MemoryPlanner, Pipeline, PipelineConfig};
use exascale_tensor::serve::{
    cache_key, model_digest, protocol, JobOutcome, JobRecord, JobSource, JobSpec, JobState,
    Request, Server, ServerConfig, SchedulerConfig, Spool,
};
use exascale_tensor::tensor::{BlockSpec3, DenseTensor, LowRankGenerator};
use exascale_tensor::util::json::Json;
use exascale_tensor::util::threadpool::ThreadPool;
use std::time::{Duration, Instant};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("exatensor_serve_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

/// The small deterministic job every test uses (seed varies the input).
fn spec(seed: u64) -> JobSpec {
    JobSpec {
        source: JobSource::Synthetic { size: 24, rank: 2, noise: 0.0, seed },
        config: PipelineConfig::builder()
            .reduced_dims(8, 8, 8)
            .rank(2)
            .anchor_rows(4)
            .block([8, 8, 8])
            .als(120, 1e-10)
            .threads(2)
            .seed(seed)
            .build()
            .unwrap(),
        priority: 0,
        tenant: String::new(),
        sharded: false,
        no_cache: false,
    }
}

/// Mirrors the scheduler's admission pricing for `spec` under an ample
/// budget: checkpointing on, no plan shrinking.
fn plan_bytes(spec: &JobSpec) -> usize {
    let mut cfg = spec.config.clone();
    cfg.checkpoint_dir = Some(std::env::temp_dir());
    MemoryPlanner::plan(&cfg, spec.source.dims().unwrap())
        .unwrap()
        .estimated_bytes
}

fn start_server(spool: &std::path::Path, sched: SchedulerConfig) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    // A generous deadline: hardening must not perturb the happy paths.
    start_server_hardened(spool, sched, 60_000, 0)
}

fn start_server_hardened(
    spool: &std::path::Path,
    sched: SchedulerConfig,
    conn_timeout_ms: u64,
    max_conns: usize,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind(&ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        spool_dir: spool.to_path_buf(),
        scheduler: sched,
        conn_timeout_ms,
        max_conns,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

fn submit(addr: &str, spec: &JobSpec) -> JobRecord {
    let resp = protocol::call_ok(addr, &Request::Submit(spec.clone())).unwrap();
    JobRecord::from_json(resp.get("job").unwrap()).unwrap()
}

fn wait_terminal(addr: &str, id: &str, timeout: Duration) -> JobRecord {
    let start = Instant::now();
    loop {
        let resp = protocol::call_ok(addr, &Request::Status(id.to_string())).unwrap();
        let rec = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
        if rec.state.is_terminal() {
            return rec;
        }
        assert!(start.elapsed() < timeout, "timed out waiting for {id}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn metric(addr: &str, key: &str) -> u64 {
    let resp = protocol::call_ok(addr, &Request::Metrics).unwrap();
    resp.get("metrics")
        .and_then(|m| m.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64
}

/// N concurrent submissions whose summed plan bytes exceed the global
/// budget: all complete, the budget is never exceeded (observed via the
/// peak gauges), queueing shows up in `admission_rejected_bytes`, a
/// repeated submission is served from cache, and `SHUTDOWN` drains
/// gracefully.
#[test]
fn daemon_admission_cache_and_graceful_shutdown() {
    let dir = tmpdir("e2e");
    let p = plan_bytes(&spec(1));
    // Budget fits one job but not two: three concurrent submissions must
    // serialize through admission even with three free workers.
    let budget = p + p / 2;
    let (addr, handle) = start_server(
        &dir,
        SchedulerConfig { memory_budget: budget, workers: 3, cache_bytes: 64 << 20, ..Default::default() },
    );

    let recs: Vec<JobRecord> = (1..=3).map(|s| submit(&addr, &spec(s))).collect();
    assert_eq!(recs[0].plan_bytes, p, "admission price must match the plan");
    assert!(3 * p > budget, "test premise: summed plans exceed the budget");

    let mut digests = Vec::new();
    for rec in &recs {
        let done = wait_terminal(&addr, &rec.id, Duration::from_secs(300));
        assert_eq!(done.state, JobState::Done, "job {}: {:?}", rec.id, done.error);
        let o = done.outcome.unwrap();
        assert!(!o.from_cache);
        assert!(o.rel_error < 0.05, "rel {}", o.rel_error);
        digests.push(o.model_digest);
    }
    assert_ne!(digests[0], digests[1], "different seeds ⇒ different results");

    // Admission control was actually exercised and never overcommitted.
    assert!(metric(&addr, "admission_rejected_bytes") > 0, "queueing must be observable");
    assert!(metric(&addr, "admission_used_bytes_peak") <= budget as u64);
    assert_eq!(metric(&addr, "jobs_running_peak"), 1, "budget admits exactly one at a time");
    assert_eq!(metric(&addr, "jobs_done"), 3);
    assert_eq!(metric(&addr, "jobs_queued"), 0);

    // Identical resubmission — from a *different tenant*: tenant is
    // scheduling metadata, not part of the cache key, so this is still a
    // hit with a bitwise-identical digest.
    let mut resub = spec(1);
    resub.tenant = "acme".into();
    let rec = submit(&addr, &resub);
    assert_eq!(rec.state, JobState::Done, "cache hit completes at submit");
    let o = rec.outcome.clone().unwrap();
    assert!(o.from_cache);
    assert_eq!(o.model_digest, digests[0]);
    assert!(metric(&addr, "cache_hits") >= 1);

    // LIST: one summary per job (id/state/tenant/priority), no full specs.
    let resp = protocol::call_ok(&addr, &Request::List).unwrap();
    let jobs = match resp.get("jobs") {
        Some(Json::Arr(v)) => v.clone(),
        other => panic!("LIST must return a jobs array, got {other:?}"),
    };
    assert_eq!(jobs.len(), 4, "3 runs + 1 cached resubmission");
    let mine = jobs
        .iter()
        .find(|j| j.get("id").and_then(|x| x.as_str()) == Some(rec.id.as_str()))
        .expect("resubmitted job listed");
    assert_eq!(mine.get("state").and_then(|x| x.as_str()), Some("done"));
    assert_eq!(mine.get("tenant").and_then(|x| x.as_str()), Some("acme"));
    assert_eq!(mine.get("priority").and_then(|x| x.as_f64()), Some(0.0));
    assert!(mine.get("spec").is_none(), "LIST summaries must stay slim");

    // RESULT returns the outcome and the spooled factor files exist.
    let resp = protocol::call_ok(&addr, &Request::Result(recs[0].id.clone())).unwrap();
    let rdir = resp.get("result_dir").and_then(|x| x.as_str()).unwrap().to_string();
    assert!(std::path::Path::new(&rdir).join("a.ext1").exists());
    let back = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
    assert_eq!(back.outcome.unwrap().model_digest, digests[0]);

    // Unknown id and premature RESULT are protocol errors, not hangs.
    assert!(protocol::call_ok(&addr, &Request::Status("job-999999".into())).is_err());

    // Graceful shutdown: the daemon drains and the accept loop exits.
    let resp = protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    assert_eq!(resp.get("draining").and_then(|x| x.as_bool()), Some(true));
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Kill/restart recovery: a daemon "killed" mid-compression (simulated by
/// authoring exactly the on-disk state it leaves behind — a `running` job
/// record in the spool plus the pipeline's incremental checkpoint) is
/// restarted on the same spool.  It must requeue the job, resume from the
/// checkpoint instead of restarting Stage 1, and produce a model digest
/// bitwise-identical to an uninterrupted run.
#[test]
fn daemon_restart_recovers_spool_and_resumes_bitwise() {
    let dir = tmpdir("recover");
    let job_spec = spec(42);

    // Reference: the same job, uninterrupted, in-process.
    let clean = {
        let src = job_spec.source.open().unwrap();
        let mut pipe = Pipeline::new(job_spec.config.clone());
        pipe.run(src.as_ref()).unwrap()
    };
    let clean_digest = model_digest(&clean.model);

    // Author the killed daemon's spool: record in state `running`, plus a
    // partial checkpoint captured mid-compression (the batched path, same
    // plan/maps/fingerprint the pipeline itself would use).
    let spool = Spool::open(&dir).unwrap();
    let ckpt = spool.checkpoint_dir("job-000001");
    let mut run_cfg = job_spec.config.clone();
    run_cfg.checkpoint_dir = Some(ckpt.clone());
    let dims = job_spec.source.dims().unwrap();
    let plan = MemoryPlanner::plan(&run_cfg, dims).unwrap();
    let maps = MapSource::generate(
        dims,
        run_cfg.reduced,
        plan.replicas,
        run_cfg.effective_anchor(),
        run_cfg.seed,
        plan.map_tier,
    );
    let fp = checkpoint::default_fingerprint(&run_cfg, dims, plan.replicas);
    let opts = StreamOptions { threads: 2, ..Default::default() };
    let blocks_total = BlockSpec3::new(dims, plan.block).num_blocks();
    let shards_total = ThreadPool::partition(blocks_total, opts.shard_parts).len();
    let partition = CompressionProgress {
        block: plan.block,
        shard_parts: opts.shard_parts,
        shards_total,
        shards_done: 0,
        blocks_done: 0,
        blocks_total,
        path: "batched".to_string(),
        generation: 0,
    };
    let gen = LowRankGenerator::new(24, 24, 24, 2, 42);
    let saved = std::sync::atomic::AtomicBool::new(false);
    let sink = |acc: &Vec<DenseTensor>, shards_done: usize, blocks_done: usize| {
        if saved.swap(true, std::sync::atomic::Ordering::SeqCst) {
            return false;
        }
        let mut pr = partition.clone();
        pr.shards_done = shards_done;
        pr.blocks_done = blocks_done;
        checkpoint::save_partial(&ckpt, &fp, &pr, acc).unwrap();
        false
    };
    let (_, stats) =
        compress_source_batched_opts(&gen, &maps, plan.block, &opts, None, Some(&sink));
    assert!(stats.aborted, "the authored checkpoint must be mid-compression");
    assert!(checkpoint::partial_exists(&ckpt));

    let rec = JobRecord {
        id: "job-000001".to_string(),
        seq: 1,
        spec: JobSpec {
            source: job_spec.source.clone(),
            config: run_cfg,
            priority: 0,
            tenant: String::new(),
            sharded: false,
            no_cache: false,
        },
        state: JobState::Running,
        plan_bytes: plan.estimated_bytes,
        cache_key: cache_key(&job_spec).unwrap(),
        cancel_requested: false,
        resolved_solver: None,
        attempts: 0,
        panics: 0,
        error: None,
        outcome: None,
    };
    spool.save(&rec).unwrap();
    drop(spool);

    // "Restart" the daemon on the crashed spool.
    let (addr, handle) = start_server(
        &dir,
        SchedulerConfig { memory_budget: 0, workers: 1, cache_bytes: 16 << 20, ..Default::default() },
    );
    assert_eq!(metric(&addr, "jobs_recovered"), 1);
    assert_eq!(metric(&addr, "jobs_resumable"), 1);
    let done = wait_terminal(&addr, "job-000001", Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "recovered job failed: {:?}", done.error);
    assert!(
        metric(&addr, "checkpoint_partial_resumed_blocks") > 0,
        "the recovered job must resume mid-compression, not restart"
    );
    assert_eq!(
        done.outcome.unwrap().model_digest,
        clean_digest,
        "kill/restart must be bitwise invisible"
    );

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Scheduler-level direct checks that don't need a socket: priority
/// ordering and spool round trips through a restart with terminal states.
#[test]
fn restart_preserves_terminal_states_over_protocol() {
    let dir = tmpdir("terminal");
    {
        let (addr, handle) = start_server(&dir, SchedulerConfig::default());
        let rec = submit(&addr, &spec(7));
        let done = wait_terminal(&addr, &rec.id, Duration::from_secs(300));
        assert_eq!(done.state, JobState::Done);
        protocol::call_ok(&addr, &Request::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }
    // New daemon, same spool: the finished record is still queryable and
    // is NOT re-run (no recovered jobs).
    let (addr, handle) = start_server(&dir, SchedulerConfig::default());
    assert_eq!(metric(&addr, "jobs_recovered"), 0);
    let resp = protocol::call_ok(&addr, &Request::Status("job-000001".into())).unwrap();
    let rec = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
    assert_eq!(rec.state, JobState::Done);
    assert!(rec.outcome.is_some());
    // And the sequence counter continues past recovered records.
    let rec2 = submit(&addr, &spec(8));
    assert_eq!(rec2.id, "job-000002");
    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// JSON protocol robustness over a raw socket: garbage lines error without
/// killing the daemon, and multiple requests share one connection.
#[test]
fn protocol_handles_garbage_and_pipelining() {
    use std::io::{BufRead, BufReader, Write};
    let dir = tmpdir("proto");
    let (addr, handle) = start_server(&dir, SchedulerConfig::default());

    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    s.write_all(b"this is not json\n").unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(false));
    drop(r);
    drop(s);

    // Two requests on one connection.
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    s.write_all(b"{\"cmd\":\"METRICS\"}\n{\"cmd\":\"STATUS\",\"id\":\"nope\"}\n")
        .unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("ok").and_then(|x| x.as_bool()),
        Some(true)
    );
    line.clear();
    r.read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("ok").and_then(|x| x.as_bool()),
        Some(false)
    );
    drop(r);

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Connection hardening: a half-open peer (connect, send nothing) and a
/// slow-loris peer (one byte per window, never a full line) are both
/// reaped on the request deadline, counted in `conn_timeouts`, and never
/// block well-behaved clients from doing real work in the meantime.
#[test]
fn slow_loris_and_half_open_peers_are_reaped_without_blocking_tenants() {
    use std::io::{Read, Write};
    let dir = tmpdir("loris");
    // Short deadline so the reap happens within the test's patience.
    let (addr, handle) = start_server_hardened(&dir, SchedulerConfig::default(), 600, 0);

    // Half-open: connect and go silent.
    let half_open = std::net::TcpStream::connect(&addr).unwrap();

    // Slow-loris: trickle a valid-looking request one byte at a time with
    // gaps longer than the per-read tick but never complete the line.
    let mut loris = std::net::TcpStream::connect(&addr).unwrap();
    let loris_feeder = std::thread::spawn(move || {
        for b in b"{\"cmd\":\"METRICS\"" {
            if loris.write_all(&[*b]).is_err() {
                break; // reaped mid-trickle — exactly what we want
            }
            std::thread::sleep(Duration::from_millis(90));
        }
        loris
    });

    // While both attackers hold sockets, an honest tenant's job completes.
    let rec = submit(&addr, &spec(11));
    let done = wait_terminal(&addr, &rec.id, Duration::from_secs(300));
    assert_eq!(done.state, JobState::Done, "honest tenant starved: {:?}", done.error);

    // Both hostile connections are reaped on the deadline: the daemon
    // sends a timeout error line (or just closes) and read returns EOF.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if metric(&addr, "conn_timeouts") >= 2 {
            break;
        }
        assert!(Instant::now() < deadline, "peers never reaped");
        std::thread::sleep(Duration::from_millis(50));
    }
    let mut buf = Vec::new();
    let mut half_open = half_open;
    half_open.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    half_open.read_to_end(&mut buf).unwrap();
    let note = String::from_utf8_lossy(&buf);
    assert!(note.contains("timed out"), "expected a polite reap note, got: {note:?}");
    drop(loris_feeder.join().unwrap());

    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Keep `JobOutcome` used in this crate's namespace (silences the import
/// when individual tests are filtered) and sanity-check digest stability.
#[test]
fn outcome_digest_matches_cache_helper() {
    let gen = LowRankGenerator::new(16, 16, 16, 2, 5);
    let cfg = PipelineConfig::builder()
        .reduced_dims(8, 8, 8)
        .rank(2)
        .anchor_rows(4)
        .block([8, 8, 8])
        .als(100, 1e-9)
        .threads(1)
        .seed(5)
        .build()
        .unwrap();
    let res = Pipeline::new(cfg).run(&gen).unwrap();
    let d1 = model_digest(&res.model);
    let d2 = model_digest(&res.model);
    assert_eq!(d1, d2);
    let o = JobOutcome {
        rel_error: res.diagnostics.rel_error,
        sampled_mse: res.diagnostics.sampled_mse,
        dropped_replicas: 0,
        model_digest: d1,
        from_cache: false,
    };
    assert_eq!(o.model_digest, d1);
}
