//! Differential property tests for the `ComputeBackend` layer: the
//! parallel CPU backend must agree with the serial reference on every
//! kernel, across random shapes (including ragged edge tiles), all
//! transpose combinations, and the MTTKRP/Khatri-Rao identities the ALS
//! sweeps rely on.  The fused zero-materialization MTTKRP (serial and both
//! parallel splits) is differential-tested against the materialized
//! `khatri_rao`+GEMM oracle it replaced.

use exascale_tensor::linalg::products::{hadamard, khatri_rao};
use exascale_tensor::linalg::{
    mttkrp_fused, mttkrp_fused_acc, mttkrp_materialized, ComputeBackend, CpuParallelBackend,
    Matrix, SerialBackend, Trans,
};
use exascale_tensor::tensor::unfold::{unfold_1, unfold_2, unfold_3};
use exascale_tensor::tensor::DenseTensor;
use exascale_tensor::util::prop;
use exascale_tensor::util::rng::Xoshiro256;

/// Parallel backend with the serial-fallback threshold disabled so even
/// tiny property-test shapes exercise the strip-split path.
fn par(threads: usize) -> CpuParallelBackend {
    CpuParallelBackend::new(threads).with_min_par_flops(0)
}

fn assert_close(got: &Matrix, want: &Matrix, tol: f64, what: &str) {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()), "{what}: shape");
    let err = got.rel_error(want);
    assert!(err < tol, "{what}: rel error {err} > {tol}");
}

#[test]
fn gemm_differential_random_shapes_all_transposes() {
    prop::check("backend-gemm-differential", 40, |g| {
        // Ragged shapes straddling the micro-kernel's 8/4/1-column blocks
        // and the MC=128 row panel.
        let m = g.int(1, 150);
        let k = g.int(1, 70);
        let n = g.int(1, 150);
        let threads = g.int(2, 6);
        let op_a = if g.bool(0.5) { Trans::Yes } else { Trans::No };
        let op_b = if g.bool(0.5) { Trans::Yes } else { Trans::No };
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let (ar, ac) = if op_a == Trans::No { (m, k) } else { (k, m) };
        let (br, bc) = if op_b == Trans::No { (k, n) } else { (n, k) };
        let a = Matrix::random_normal(ar, ac, &mut rng);
        let b = Matrix::random_normal(br, bc, &mut rng);

        let serial = SerialBackend.matmul(&a, op_a, &b, op_b);
        let parallel = par(threads).matmul(&a, op_a, &b, op_b);
        assert_close(&parallel, &serial, 1e-4, "gemm");
    });
}

#[test]
fn gemm_differential_alpha_beta() {
    prop::check("backend-gemm-alpha-beta", 25, |g| {
        let m = g.int(1, 60);
        let k = g.int(1, 40);
        let n = g.int(1, 60);
        let alpha = g.f32(-2.0, 2.0);
        let beta = if g.bool(0.3) { 0.0 } else { g.f32(-1.5, 1.5) };
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let a = Matrix::random_normal(m, k, &mut rng);
        let b = Matrix::random_normal(k, n, &mut rng);
        let c0 = Matrix::random_normal(m, n, &mut rng);

        let mut c_ser = c0.clone();
        SerialBackend.gemm(alpha, &a, Trans::No, &b, Trans::No, beta, &mut c_ser);
        let mut c_par = c0.clone();
        par(4).gemm(alpha, &a, Trans::No, &b, Trans::No, beta, &mut c_par);
        // Absolute-scale comparison: alpha/beta may cancel the result.
        let diff = c_par.sub(&c_ser).frobenius_norm();
        let scale = c_ser.frobenius_norm().max(1.0);
        assert!(diff / scale < 1e-4, "alpha-beta diff {diff} scale {scale}");
    });
}

#[test]
fn mttkrp_differential_all_modes() {
    prop::check("backend-mttkrp-differential", 25, |g| {
        let dims = [g.int(2, 14), g.int(2, 12), g.int(2, 10)];
        let r = g.int(1, 5);
        let threads = g.int(2, 5);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let t = DenseTensor::random_normal(dims, &mut rng);
        let a = Matrix::random_normal(dims[0], r, &mut rng);
        let b = Matrix::random_normal(dims[1], r, &mut rng);
        let c = Matrix::random_normal(dims[2], r, &mut rng);

        let pairs = [
            (1usize, unfold_1(&t), &c, &b),
            (2, unfold_2(&t), &c, &a),
            (3, unfold_3(&t), &b, &a),
        ];
        for (mode, x_mode, slow, fast) in pairs {
            let serial = SerialBackend.mttkrp(mode, &x_mode, slow, fast);
            let parallel = par(threads).mttkrp(mode, &x_mode, slow, fast);
            assert_close(&parallel, &serial, 1e-4, &format!("mttkrp mode {mode}"));
        }
    });
}

#[test]
fn mttkrp_khatri_rao_unfold_identity() {
    // For X = [[A, B, C]] exactly, X_(1)·(C ⊙ B) = A·(CᵀC * BᵀB): the
    // identity every ALS normal equation is built on.  Check it per mode
    // on both backends.
    prop::check("mttkrp-kr-identity", 20, |g| {
        let dims = [g.int(2, 10), g.int(2, 10), g.int(2, 10)];
        let r = g.int(1, 4);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let a = Matrix::random_normal(dims[0], r, &mut rng);
        let b = Matrix::random_normal(dims[1], r, &mut rng);
        let c = Matrix::random_normal(dims[2], r, &mut rng);
        let t = DenseTensor::from_cp_factors(&a, &b, &c);

        let parallel = par(3);
        let backends: [&dyn ComputeBackend; 2] = [&SerialBackend, &parallel];
        let cases = [
            (1usize, unfold_1(&t), &c, &b, &a),
            (2, unfold_2(&t), &c, &a, &b),
            (3, unfold_3(&t), &b, &a, &c),
        ];
        for be in backends {
            for case in &cases {
                let (mode, x_mode, slow, fast, factor) = case;
                let (mode, slow, fast, factor) = (*mode, *slow, *fast, *factor);
                let mttkrp = be.mttkrp(mode, x_mode, slow, fast);
                let want = be.matmul(
                    factor,
                    Trans::No,
                    &hadamard(&be.gram(slow), &be.gram(fast)),
                    Trans::No,
                );
                assert_close(&mttkrp, &want, 1e-3, &format!("identity mode {mode}"));
            }
        }
    });
}

#[test]
fn fused_mttkrp_differential_vs_materialized_all_modes() {
    // The fused kernel (serial default + parallel panel/row splits) against
    // the materialized khatri_rao+GEMM oracle, random shapes, every mode.
    prop::check("fused-mttkrp-vs-materialized", 30, |g| {
        let dims = [g.int(1, 14), g.int(1, 12), g.int(1, 10)];
        let r = g.int(1, 6);
        let threads = g.int(2, 5);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let t = DenseTensor::random_normal(dims, &mut rng);
        let a = Matrix::random_normal(dims[0], r, &mut rng);
        let b = Matrix::random_normal(dims[1], r, &mut rng);
        let c = Matrix::random_normal(dims[2], r, &mut rng);

        let cases = [
            (1usize, unfold_1(&t), &c, &b),
            (2, unfold_2(&t), &c, &a),
            (3, unfold_3(&t), &b, &a),
        ];
        for (mode, x_mode, slow, fast) in cases {
            let oracle = mttkrp_materialized(&x_mode, slow, fast);
            let direct = mttkrp_fused(&x_mode, slow, fast);
            assert_close(&direct, &oracle, 1e-4, &format!("fused direct mode {mode}"));
            let serial = SerialBackend.mttkrp(mode, &x_mode, slow, fast);
            assert_close(&serial, &oracle, 1e-4, &format!("fused serial mode {mode}"));
            let parallel = par(threads).mttkrp(mode, &x_mode, slow, fast);
            assert_close(&parallel, &oracle, 1e-4, &format!("fused parallel mode {mode}"));
        }
    });
}

#[test]
fn fused_mttkrp_degenerate_dims() {
    // Degenerate tensors — 1×n×1 and friends — hit the fused kernel's
    // panel-counter edge cases (J = 1 wraps every step; K = 1 never wraps)
    // and the parallel backend's split-selection boundaries.
    let mut rng = Xoshiro256::seed_from_u64(79);
    for dims in [[1usize, 17, 1], [9, 1, 1], [1, 1, 9], [1, 1, 1], [2, 1, 13]] {
        let t = DenseTensor::random_normal(dims, &mut rng);
        let r = 3;
        let a = Matrix::random_normal(dims[0], r, &mut rng);
        let b = Matrix::random_normal(dims[1], r, &mut rng);
        let c = Matrix::random_normal(dims[2], r, &mut rng);
        let cases = [
            (1usize, unfold_1(&t), &c, &b),
            (2, unfold_2(&t), &c, &a),
            (3, unfold_3(&t), &b, &a),
        ];
        for (mode, x_mode, slow, fast) in cases {
            let oracle = mttkrp_materialized(&x_mode, slow, fast);
            let what = format!("degenerate {dims:?} mode {mode}");
            assert_close(&SerialBackend.mttkrp(mode, &x_mode, slow, fast), &oracle, 1e-4, &what);
            assert_close(&par(4).mttkrp(mode, &x_mode, slow, fast), &oracle, 1e-4, &what);
        }
    }
}

#[test]
fn fused_acc_split_invariants() {
    // The exact-splitting contract the parallel backend relies on: panel
    // partitions sum to the full MTTKRP; row strips stack to it.
    let mut rng = Xoshiro256::seed_from_u64(80);
    let (i, j, k, r) = (21usize, 6usize, 13usize, 4usize);
    let x = Matrix::random_normal(i, j * k, &mut rng);
    let fast = Matrix::random_normal(j, r, &mut rng);
    let slow = Matrix::random_normal(k, r, &mut rng);
    let oracle = mttkrp_materialized(&x, &slow, &fast);

    let mut acc = Matrix::zeros(i, r);
    for (k0, k1) in [(0usize, 5usize), (5, 6), (6, 13)] {
        mttkrp_fused_acc(&x, 0..i, k0..k1, &slow, &fast, &mut acc);
    }
    assert_close(&acc, &oracle, 1e-4, "panel partition sum");

    let mut strips = Vec::new();
    for (i0, i1) in [(0usize, 8usize), (8, 9), (9, 21)] {
        let mut part = Matrix::zeros(i1 - i0, r);
        mttkrp_fused_acc(&x, i0..i1, 0..k, &slow, &fast, &mut part);
        strips.push(part);
    }
    let stacked = Matrix::vstack(&strips.iter().collect::<Vec<_>>());
    assert_close(&stacked, &oracle, 1e-4, "row strip stack");
}

#[test]
fn mttkrp_equals_explicit_khatri_rao_product() {
    let mut rng = Xoshiro256::seed_from_u64(77);
    let t = DenseTensor::random_normal([9, 8, 7], &mut rng);
    let b = Matrix::random_normal(8, 3, &mut rng);
    let c = Matrix::random_normal(7, 3, &mut rng);
    let x1 = unfold_1(&t);
    let kr = khatri_rao(&c, &b);
    let explicit = SerialBackend.matmul(&x1, Trans::No, &kr, Trans::No);
    let parallel = par(4);
    let backends: [&dyn ComputeBackend; 2] = [&SerialBackend, &parallel];
    for be in backends {
        assert_close(&be.mttkrp(1, &x1, &c, &b), &explicit, 1e-5, "explicit kr");
    }
}

#[test]
fn gemm_batch_differential() {
    prop::check("backend-gemm-batch", 20, |g| {
        let items = g.int(1, 9);
        let l = g.int(1, 20);
        let dj = g.int(1, 20);
        let m = g.int(1, 20);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        // The per-block compression shape: many small (l × dj) slices
        // against one shared (m × dj) map slice, transposed.
        let v_blk = Matrix::random_normal(m, dj, &mut rng);
        let slices: Vec<Matrix> = (0..items)
            .map(|_| Matrix::random_normal(l, dj, &mut rng))
            .collect();

        let mut serial: Vec<Matrix> = (0..items).map(|_| Matrix::zeros(l, m)).collect();
        SerialBackend.gemm_batch(1.0, &slices, Trans::No, &v_blk, Trans::Yes, 0.0, &mut serial);
        let mut parallel: Vec<Matrix> = (0..items).map(|_| Matrix::zeros(l, m)).collect();
        par(4).gemm_batch(1.0, &slices, Trans::No, &v_blk, Trans::Yes, 0.0, &mut parallel);

        for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
            assert_close(p, s, 1e-5, &format!("batch item {i}"));
            let direct = SerialBackend.matmul(&slices[i], Trans::No, &v_blk, Trans::Yes);
            assert_close(s, &direct, 1e-5, &format!("batch vs direct {i}"));
        }
    });
}

#[test]
fn gram_differential() {
    prop::check("backend-gram", 20, |g| {
        let rows = g.int(1, 200);
        let r = g.int(1, 8);
        let mut rng = Xoshiro256::seed_from_u64(g.int(0, 1 << 30) as u64);
        let f = Matrix::random_normal(rows, r, &mut rng);
        assert_close(&par(4).gram(&f), &SerialBackend.gram(&f), 1e-4, "gram");
    });
}

#[test]
fn matvec_matches_gemm_on_both_backends() {
    let mut rng = Xoshiro256::seed_from_u64(78);
    let a = Matrix::random_normal(31, 17, &mut rng);
    let x: Vec<f32> = rng.gaussian_vec_f32(17);
    let xm = Matrix::from_vec(17, 1, x.clone());
    let want = SerialBackend.matmul(&a, Trans::No, &xm, Trans::No);
    let parallel = par(3);
    let backends: [&dyn ComputeBackend; 2] = [&SerialBackend, &parallel];
    for be in backends {
        let y = be.matvec(&a, Trans::No, &x);
        for i in 0..31 {
            assert!((y[i] - want.get(i, 0)).abs() < 1e-5, "{} matvec row {i}", be.name());
        }
    }
}
