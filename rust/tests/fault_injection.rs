//! Chaos suite: deterministic fault injection against the real stack.
//!
//! Every test arms a seeded [`FaultPlan`] (or explicitly excludes faults)
//! and drives production code paths end to end — no mocks.  Across the
//! suite every named site delivers at least one fault:
//!
//! * `io_read`            — retried bitwise + exhausted-retry checkpoint-then-fail
//! * `io_write`           — transient error surfaced, retry lands the payload
//! * `checkpoint_commit`  — failed commit is transient, fallback generation intact
//! * `worker_panic`       — poison job quarantined while other tenants complete
//! * `conn_stall`         — stalled connection reaped and counted
//!
//! Fault state is process-global, so every test serializes through
//! [`lock`]; the suite supports a `CHAOS_QUICK=1` env (CI smoke mode) that
//! shrinks problem sizes without dropping any site's coverage.

use exascale_tensor::compress::{compress_source_batched_opts, MapSource, StreamOptions};
use exascale_tensor::coordinator::checkpoint::{self, CompressionProgress};
use exascale_tensor::coordinator::{MemoryPlanner, Pipeline, PipelineConfig};
use exascale_tensor::serve::{
    model_digest, protocol, JobRecord, JobSource, JobSpec, JobState, Request, Server,
    ServerConfig, SchedulerConfig,
};
use exascale_tensor::tensor::{
    io, save_tensor_streamed, BlockSpec3, DenseTensor, FileTensorSource, LowRankGenerator,
};
use exascale_tensor::util::fault::{
    arm_scoped, exclude_faults, is_transient, should_fault, FaultPlan, Site, SiteSpec, ALL_SITES,
};
use exascale_tensor::util::threadpool::ThreadPool;
use std::sync::atomic::Ordering;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Serializes the whole suite: armed plans and the I/O telemetry statics
/// are process-global, so concurrently running chaos tests would observe
/// each other's faults.
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// CI smoke mode: smaller tensors, same site coverage.
fn quick() -> bool {
    std::env::var("CHAOS_QUICK").map(|v| v == "1").unwrap_or(false)
}

fn size() -> usize {
    if quick() { 16 } else { 24 }
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("exatensor_chaos_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// The small deterministic pipeline config the whole suite uses.
fn cfg(seed: u64, threads: usize) -> PipelineConfig {
    PipelineConfig::builder()
        .reduced_dims(8, 8, 8)
        .rank(2)
        .anchor_rows(4)
        .block([8, 8, 8])
        .als(if quick() { 80 } else { 120 }, 1e-10)
        .threads(threads)
        .seed(seed)
        .build()
        .unwrap()
}

/// Authors an `EXT1` tensor file for the file-backed (I/O-faulted) tests.
/// Callers hold [`lock`] and have no plan armed yet (or hold an exclusion
/// guard), so the write streams fault-free.
fn tensor_file(dir: &std::path::Path, seed: u64) -> std::path::PathBuf {
    let s = size();
    let gen = LowRankGenerator::new(s, s, s, 2, seed);
    let path = dir.join("input.ext1");
    save_tensor_streamed(&gen, &path, 8).unwrap();
    path
}

// ---------------------------------------------------------------- inertness

/// Compiled-in fault sites must be provably inert when no plan is armed:
/// identical digests run to run, zero retry telemetry, every probe false.
#[test]
fn unarmed_fault_sites_are_inert() {
    let _t = lock();
    let _no_faults = exclude_faults();
    for site in ALL_SITES {
        assert!(!should_fault(site), "{} probed true while unarmed", site.name());
    }
    let dir = tmpdir("inert");
    let path = tensor_file(&dir, 3);
    let retries_before = io::IO_RETRIES.load(Ordering::SeqCst);
    let gave_up_before = io::IO_GAVE_UP.load(Ordering::SeqCst);
    let digest = |_: usize| {
        let src = FileTensorSource::open(&path).unwrap();
        let res = Pipeline::new(cfg(3, 2)).run(&src).unwrap();
        model_digest(&res.model)
    };
    assert_eq!(digest(0), digest(1), "unarmed runs must be bitwise identical");
    assert_eq!(
        io::IO_RETRIES.load(Ordering::SeqCst),
        retries_before,
        "unarmed runs must not retry"
    );
    assert_eq!(io::IO_GAVE_UP.load(Ordering::SeqCst), gave_up_before);
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------------------ io_read

/// Transient read faults on a strict period are absorbed by the retry loop:
/// the faulted run's model is bitwise identical to the clean run's, and the
/// retries are visible in telemetry.
#[test]
fn injected_read_faults_retry_to_a_bitwise_identical_result() {
    let _t = lock();
    let dir = tmpdir("retry");
    let path = tensor_file(&dir, 5);
    // Single-threaded + no prefetch: the probe stream is sequential, so
    // `period >= 2` guarantees every faulted read's immediate retry lands
    // on a non-faulting schedule position.
    let run = || {
        let src = FileTensorSource::open(&path).unwrap();
        let mut pipe = Pipeline::new({
            let mut c = cfg(5, 1);
            c.prefetch_depth = Some(0);
            c
        });
        let res = pipe.run(&src).unwrap();
        (model_digest(&res.model), pipe.metrics.counter("io_retries"))
    };
    let (clean, _) = {
        let _no_faults = exclude_faults();
        run()
    };
    let g = arm_scoped(
        FaultPlan::new(11)
            .site(Site::IoRead, SiteSpec { period: 3, max: 50, ..Default::default() }),
    );
    let (faulted, retries) = run();
    assert!(g.fired(Site::IoRead) >= 1, "the plan must actually deliver read faults");
    assert!(retries >= 1, "faults must surface as retries in the pipeline metrics");
    assert_eq!(faulted, clean, "retried faults must be bitwise invisible");
    std::fs::remove_dir_all(&dir).ok();
}

/// A read whose retry budget is exhausted (every attempt faults) fails the
/// run — but only after the engine hands back the intact folded shard
/// prefix and the pipeline checkpoints it.  The surfaced error carries the
/// transient marker (what the scheduler's retry policy classifies on), and
/// a re-run resumes mid-stream to a bitwise-identical model.
#[test]
fn exhausted_read_retries_checkpoint_the_folded_prefix_then_resume_is_bitwise() {
    let _t = lock();
    let dir = tmpdir("giveup");
    let path = tensor_file(&dir, 7);
    let ckpt = dir.join("ckpt");

    let clean = {
        let _no_faults = exclude_faults();
        let src = FileTensorSource::open(&path).unwrap();
        let res = Pipeline::new(cfg(7, 2)).run(&src).unwrap();
        model_digest(&res.model)
    };

    let mut run_cfg = cfg(7, 2);
    run_cfg.checkpoint_dir = Some(ckpt.clone());

    // Let roughly half of stage 1's block reads through, then fault every
    // attempt: the next read exhausts its whole retry budget and gives up.
    let s = size();
    let block_reads = (s / 8) * (s / 8) * (s / 8) * 64;
    let g = arm_scoped(FaultPlan::new(13).site(
        Site::IoRead,
        SiteSpec { period: 1, after: (block_reads / 2) as u64, ..Default::default() },
    ));
    let mut pipe1 = Pipeline::new(run_cfg.clone());
    let src = FileTensorSource::open(&path).unwrap();
    let err = match pipe1.run(&src) {
        Ok(_) => panic!("an exhausted retry budget must fail the run"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(is_transient(&msg), "exhausted retries must classify as transient: {msg}");
    assert!(msg.contains("compression failed"), "unexpected failure shape: {msg}");
    assert!(g.fired(Site::IoRead) >= 5, "4 retries + the giving-up attempt");
    assert!(pipe1.metrics.counter("io_retries") >= 4);
    assert!(pipe1.metrics.counter("io_gave_up") >= 1);
    assert!(
        checkpoint::partial_exists(&ckpt),
        "the folded prefix must be checkpointed before the run fails"
    );
    drop(g);

    // The "retry" (what the scheduler does for a transient job failure):
    // same config, same checkpoint dir — resumes, does not restart.
    let mut pipe2 = Pipeline::new(run_cfg);
    let src = FileTensorSource::open(&path).unwrap();
    let res = pipe2.run(&src).unwrap();
    assert!(
        pipe2.metrics.counter("checkpoint_partial_resumed_blocks") > 0,
        "the retried run must resume the checkpointed prefix"
    );
    assert_eq!(model_digest(&res.model), clean, "faulted-then-retried must be bitwise clean");
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------------- io_write

/// A faulted payload write surfaces a transient error (the file is torn —
/// that is the caller's tmp+rename / generation-fallback problem), and the
/// retry round-trips bitwise.
#[test]
fn io_write_fault_surfaces_transiently_and_a_retry_lands_the_payload() {
    let _t = lock();
    let dir = tmpdir("write");
    let t = DenseTensor::from_vec(
        [4, 4, 4],
        (0..64).map(|i| (i as f32 * 0.37).sin()).collect(),
    );
    let path = dir.join("out.ext1");
    let g = arm_scoped(
        FaultPlan::new(17)
            .site(Site::IoWrite, SiteSpec { max: 1, ..Default::default() }),
    );
    let err = io::save_tensor(&t, &path).expect_err("armed write must fail");
    assert!(is_transient(&format!("{err:#}")));
    assert_eq!(g.fired(Site::IoWrite), 1);
    // The fault budget is spent: the retry succeeds while still armed.
    io::save_tensor(&t, &path).unwrap();
    assert_eq!(io::load_tensor(&path).unwrap(), t);
    drop(g);
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------- checkpoint_commit

/// A faulted checkpoint commit is transient and leaves no torn state: the
/// retry commits, and the committed generation loads back clean.
#[test]
fn checkpoint_commit_fault_is_transient_and_a_retry_commits() {
    let _t = lock();
    let dir = tmpdir("commit");
    let c = cfg(0, 2);
    let dims = [size(); 3];
    let fp = checkpoint::default_fingerprint(&c, dims, 2);
    let progress = CompressionProgress {
        block: [8, 8, 8],
        shard_parts: 32,
        shards_total: 4,
        shards_done: 2,
        blocks_done: 2,
        blocks_total: 4,
        path: "batched".to_string(),
        generation: 0,
    };
    let proxies: Vec<DenseTensor> = (0..2)
        .map(|p| {
            DenseTensor::from_vec(
                [8, 8, 8],
                (0..512).map(|i| ((i + p * 512) as f32 * 0.11).cos()).collect(),
            )
        })
        .collect();

    let g = arm_scoped(
        FaultPlan::new(19)
            .site(Site::CheckpointCommit, SiteSpec { max: 1, ..Default::default() }),
    );
    let err = checkpoint::save_partial(&dir, &fp, &progress, &proxies)
        .expect_err("armed commit must fail");
    assert!(is_transient(&format!("{err:#}")));
    assert_eq!(g.fired(Site::CheckpointCommit), 1);
    assert!(!checkpoint::partial_exists(&dir), "a failed commit must not tear state");
    // Budget spent: the retry commits while still armed.
    checkpoint::save_partial(&dir, &fp, &progress, &proxies).unwrap();
    drop(g);
    let load = checkpoint::load_partial(&dir, &fp, &progress).unwrap();
    let (pr, back) = load.state.expect("committed generation must load");
    assert_eq!(load.fallbacks, 0);
    assert_eq!(pr.shards_done, 2);
    assert_eq!(back, proxies);
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end generation fallback: two committed generations, the newest
/// corrupted on disk.  The pipeline must fall back to the previous intact
/// generation, count it, and still finish bitwise identical to a clean run.
#[test]
fn corrupted_generation_falls_back_to_the_previous_and_resumes_bitwise() {
    let _t = lock();
    let _no_faults = exclude_faults();
    let dir = tmpdir("fallback");
    let ckpt = dir.join("ckpt");
    let s = size();
    let gen = LowRankGenerator::new(s, s, s, 2, 23);

    let clean = {
        let res = Pipeline::new(cfg(23, 2)).run(&gen).unwrap();
        model_digest(&res.model)
    };

    // Author two checkpoint generations exactly the way the pipeline does
    // (same plan, maps, fingerprint), aborting after the second commit.
    let mut run_cfg = cfg(23, 2);
    run_cfg.checkpoint_dir = Some(ckpt.clone());
    let dims = [s; 3];
    let plan = MemoryPlanner::plan(&run_cfg, dims).unwrap();
    let maps = MapSource::generate(
        dims,
        run_cfg.reduced,
        plan.replicas,
        run_cfg.effective_anchor(),
        run_cfg.seed,
        plan.map_tier,
    );
    let fp = checkpoint::default_fingerprint(&run_cfg, dims, plan.replicas);
    // One worker: in sync mode `stop` is honored between shards, so after
    // the sink aborts no second worker can complete another shard and fire
    // it a third time — exactly generations 0 and 1 land on disk.
    let opts = StreamOptions { threads: 1, ..Default::default() };
    let blocks_total = BlockSpec3::new(dims, plan.block).num_blocks();
    let shards_total = ThreadPool::partition(blocks_total, opts.shard_parts).len();
    let partition = CompressionProgress {
        block: plan.block,
        shard_parts: opts.shard_parts,
        shards_total,
        shards_done: 0,
        blocks_done: 0,
        blocks_total,
        path: "batched".to_string(),
        generation: 0,
    };
    let calls = std::sync::atomic::AtomicU64::new(0);
    let sink = |acc: &Vec<DenseTensor>, shards_done: usize, blocks_done: usize| {
        let n = calls.fetch_add(1, Ordering::SeqCst);
        let mut pr = partition.clone();
        pr.shards_done = shards_done;
        pr.blocks_done = blocks_done;
        pr.generation = n;
        checkpoint::save_partial(&ckpt, &fp, &pr, acc).unwrap();
        n == 0 // stop after the second committed generation
    };
    let (_, stats) =
        compress_source_batched_opts(&gen, &maps, plan.block, &opts, None, Some(&sink));
    assert!(stats.aborted, "the authored checkpoint must be mid-compression");
    assert!(calls.load(Ordering::SeqCst) >= 2, "need two generations on disk");
    assert!(ckpt.join("partial_prev.json").exists());

    // Corrupt generation 1's first proxy payload (bit-rot in the newest
    // generation; generation 0's files are untouched).
    let victim = ckpt.join("partial_00000001_proxy_0000.ext1");
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    for b in &mut bytes[mid..mid + 8] {
        *b ^= 0xFF;
    }
    std::fs::write(&victim, &bytes).unwrap();

    let mut pipe = Pipeline::new(run_cfg);
    let res = pipe.run(&gen).unwrap();
    assert!(
        pipe.metrics.counter("checkpoint_fallbacks") >= 1,
        "the corrupt newest generation must be detected and skipped"
    );
    assert!(
        pipe.metrics.counter("checkpoint_partial_resumed_blocks") > 0,
        "the previous generation must actually be resumed, not cold-started"
    );
    assert_eq!(
        model_digest(&res.model),
        clean,
        "resuming the fallback generation must be bitwise invisible"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------------- daemon chaos

fn spec(seed: u64) -> JobSpec {
    let s = size();
    JobSpec {
        source: JobSource::Synthetic { size: s, rank: 2, noise: 0.0, seed },
        config: cfg(seed, 2),
        priority: 0,
        tenant: String::new(),
        sharded: false,
        no_cache: false,
    }
}

fn start_server(
    spool: &std::path::Path,
    sched: SchedulerConfig,
    conn_timeout_ms: u64,
    max_conns: usize,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind(&ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        spool_dir: spool.to_path_buf(),
        scheduler: sched,
        conn_timeout_ms,
        max_conns,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());
    (addr, handle)
}

fn submit(addr: &str, spec: &JobSpec) -> JobRecord {
    let resp = protocol::call_ok(addr, &Request::Submit(spec.clone())).unwrap();
    JobRecord::from_json(resp.get("job").unwrap()).unwrap()
}

fn wait_terminal(addr: &str, id: &str, timeout: Duration) -> JobRecord {
    let start = Instant::now();
    loop {
        let resp = protocol::call_ok(addr, &Request::Status(id.to_string())).unwrap();
        let rec = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
        if rec.state.is_terminal() {
            return rec;
        }
        assert!(start.elapsed() < timeout, "timed out waiting for {id}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn metric(addr: &str, key: &str) -> u64 {
    let resp = protocol::call_ok(addr, &Request::Metrics).unwrap();
    resp.get("metrics")
        .and_then(|m| m.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64
}

/// The multi-tenant survival test: one poison job panics on every run
/// attempt (keyed `worker_panic` faults) while a half-open peer squats on a
/// connection.  The daemon must retry then quarantine the poison job, reap
/// the stalled peer, and complete the honest tenant's job untouched.
#[test]
fn poison_job_is_quarantined_while_other_tenants_complete() {
    let _t = lock();
    let dir = tmpdir("poison");
    // Short request deadline so the half-open peer is reaped mid-test.
    let (addr, handle) = start_server(&dir, SchedulerConfig::default(), 1_200, 0);

    // The poison job is the first submission (scheduler seq 1): the keyed
    // plan aims every fault at it and at nothing else.
    let g = arm_scoped(FaultPlan::new(29).site(
        Site::WorkerPanic,
        SiteSpec { max: 5, key: Some(1), ..Default::default() },
    ));
    let _half_open = std::net::TcpStream::connect(&addr).unwrap();
    let poison = submit(&addr, &spec(31));
    let honest = submit(&addr, &spec(32));

    let bad = wait_terminal(&addr, &poison.id, Duration::from_secs(300));
    assert_eq!(bad.state, JobState::Quarantined, "poison job must be parked: {:?}", bad.error);
    assert_eq!(bad.panics, 2, "default poison threshold is two panicking runs");
    assert!(bad.error.as_deref().unwrap_or("").contains("panicked"), "{:?}", bad.error);
    assert!(g.fired(Site::WorkerPanic) >= 2);

    let good = wait_terminal(&addr, &honest.id, Duration::from_secs(300));
    assert_eq!(good.state, JobState::Done, "honest tenant must survive: {:?}", good.error);
    drop(g);

    assert!(metric(&addr, "jobs_retried") >= 1, "the first panic must requeue");
    assert_eq!(metric(&addr, "jobs_quarantined"), 1);
    let deadline = Instant::now() + Duration::from_secs(30);
    while metric(&addr, "conn_timeouts") < 1 {
        assert!(Instant::now() < deadline, "half-open peer never reaped");
        std::thread::sleep(Duration::from_millis(50));
    }

    // Quarantine is durable: a restarted daemon must not resurrect the job.
    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    let (addr, handle) = start_server(&dir, SchedulerConfig::default(), 30_000, 0);
    let resp = protocol::call_ok(&addr, &Request::Status(poison.id.clone())).unwrap();
    let rec = JobRecord::from_json(resp.get("job").unwrap()).unwrap();
    assert_eq!(rec.state, JobState::Quarantined, "quarantine must survive restart");
    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

// --------------------------------------------------------------- conn_stall

/// The `conn_stall` site replays the reap path without the wait: the
/// connection gets the timeout error line, `conn_timeouts` counts it, and
/// later connections are unaffected once the budget is spent.
#[test]
fn conn_stall_fault_reaps_the_connection_and_counts_it() {
    use std::io::{BufRead, BufReader};
    let _t = lock();
    let dir = tmpdir("stall");
    let (addr, handle) = start_server(&dir, SchedulerConfig::default(), 30_000, 0);

    let g = arm_scoped(
        FaultPlan::new(37)
            .site(Site::ConnStall, SiteSpec { max: 1, ..Default::default() }),
    );
    let s = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("timed out"), "stalled connection must get the reap note: {line:?}");
    assert_eq!(g.fired(Site::ConnStall), 1);
    drop(g);

    assert!(metric(&addr, "conn_timeouts") >= 1);
    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------------- capacity

/// Over the concurrent-connection bound, new peers get a polite error line
/// instead of silence, and capacity frees as soon as a holder disconnects.
#[test]
fn over_capacity_connections_get_a_polite_rejection() {
    use std::io::{BufRead, BufReader};
    let _t = lock();
    let _no_faults = exclude_faults();
    let dir = tmpdir("capacity");
    let (addr, handle) = start_server(&dir, SchedulerConfig::default(), 60_000, 1);

    let holder = std::net::TcpStream::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // let the acceptor register it

    let over = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(over);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(
        line.contains("connection capacity"),
        "over-capacity peer must get the polite line: {line:?}"
    );
    drop(r);
    drop(holder);

    // The holder's slot frees on EOF; normal service resumes.
    let deadline = Instant::now() + Duration::from_secs(30);
    let rejected = loop {
        match protocol::call_ok(&addr, &Request::Metrics) {
            Ok(resp) => {
                break resp
                    .get("metrics")
                    .and_then(|m| m.get("conn_rejected_over_capacity"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64
            }
            Err(_) => {
                assert!(Instant::now() < deadline, "capacity never freed");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    assert!(rejected >= 1);
    protocol::call_ok(&addr, &Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
