//! Differential tests for the tiered replica-map source: the procedural
//! (generate-on-slice) tier must be **bitwise indistinguishable** from the
//! materialized tier everywhere results can be observed — streaming
//! compression across block shapes / thread counts / prefetch settings,
//! kill/resume across a *tier swap*, the panel-streamed stacked recovery,
//! and the full budgeted pipeline (the ISSUE 5 acceptance criterion).

use exascale_tensor::compress::{
    compress_source_batched_opts, compress_source_opts, MapSource, MapTier, PrefetchConfig,
    ResumeState, RustCompressor, StreamOptions,
};
use exascale_tensor::coordinator::checkpoint::{self, CompressionProgress};
use exascale_tensor::coordinator::{MapTierChoice, Pipeline, PipelineConfig, PipelineResult};
use exascale_tensor::cp::CpModel;
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::tensor::{BlockSpec3, DenseTensor, LowRankGenerator};
use exascale_tensor::util::threadpool::ThreadPool;

fn tmppath(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("exatensor_maptier_{name}_{}", std::process::id()));
    p
}

fn assert_models_bitwise(a: &CpModel, b: &CpModel, what: &str) {
    assert_eq!(a.a.data(), b.a.data(), "{what}: factor A differs");
    assert_eq!(a.b.data(), b.b.data(), "{what}: factor B differs");
    assert_eq!(a.c.data(), b.c.data(), "{what}: factor C differs");
}

/// Streaming compression: the tier must be invisible at every schedule —
/// thread counts, prefetch, block shapes, and both per-block chains.
#[test]
fn compression_tier_invariant_across_schedules() {
    let gen = LowRankGenerator::new(22, 20, 18, 2, 600);
    let mk = |tier| MapSource::generate([22, 20, 18], [6, 5, 4], 3, 2, 601, tier);
    let mat = mk(MapTier::Materialized);
    let proc_ = mk(MapTier::Procedural);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    for block in [[22, 20, 18], [7, 6, 5]] {
        for threads in [1, 4] {
            for prefetch in [None, Some(PrefetchConfig { depth: 3, io_threads: 2 })] {
                let opts = StreamOptions { threads, prefetch, ..Default::default() };
                let a = compress_source_opts(&gen, &mat, block, &comp, &opts, None, None).0;
                let b = compress_source_opts(&gen, &proc_, block, &comp, &opts, None, None).0;
                assert_eq!(a, b, "trait path block={block:?} threads={threads}");
                let ab = compress_source_batched_opts(&gen, &mat, block, &opts, None, None).0;
                let bb = compress_source_batched_opts(&gen, &proc_, block, &opts, None, None).0;
                assert_eq!(ab, bb, "batched path block={block:?} threads={threads}");
                assert_eq!(a, ab, "trait vs batched disagree on identical maps");
            }
        }
    }
}

/// Kill/resume with a **tier swap**: a mid-compression checkpoint written
/// by a materialized-tier run resumes under the procedural tier (and vice
/// versa) bitwise-identically — the fingerprint deliberately excludes the
/// tier because the maps it regenerates from the seed are identical.
#[test]
fn kill_resume_swaps_tiers_bitwise() {
    let gen = LowRankGenerator::new(24, 24, 24, 2, 610);
    let mk = |tier| MapSource::generate([24, 24, 24], [6, 6, 6], 3, 2, 611, tier);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let block = [5, 5, 5];
    let opts = StreamOptions { threads: 2, ..Default::default() };
    let blocks_total = BlockSpec3::new([24, 24, 24], block).num_blocks();
    let shards_total = ThreadPool::partition(blocks_total, opts.shard_parts).len();
    let fp = checkpoint::Fingerprint {
        dims: [24, 24, 24],
        reduced: [6, 6, 6],
        rank: 2,
        replicas: 3,
        anchor_rows: 2,
        seed: 611,
        mixed_precision: false,
    };
    let partition = CompressionProgress {
        block,
        shard_parts: opts.shard_parts,
        shards_total,
        shards_done: 0,
        blocks_done: 0,
        blocks_total,
        path: "plain".to_string(),
        generation: 0,
    };
    let reference =
        compress_source_opts(&gen, &mk(MapTier::Materialized), block, &comp, &opts, None, None).0;

    for (first, second) in [
        (MapTier::Materialized, MapTier::Procedural),
        (MapTier::Procedural, MapTier::Materialized),
    ] {
        let dir = tmppath(&format!("swap_{}", first.as_str()));
        let saved = std::sync::atomic::AtomicBool::new(false);
        let sink = |acc: &Vec<DenseTensor>, shards_done: usize, blocks_done: usize| {
            if saved.swap(true, std::sync::atomic::Ordering::SeqCst) {
                return false;
            }
            let mut pr = partition.clone();
            pr.shards_done = shards_done;
            pr.blocks_done = blocks_done;
            checkpoint::save_partial(&dir, &fp, &pr, acc).unwrap();
            false
        };
        let (_, stats) =
            compress_source_opts(&gen, &mk(first), block, &comp, &opts, None, Some(&sink));
        assert!(stats.aborted, "the kill must interrupt the pass");

        let (pr, acc) = checkpoint::load_partial(&dir, &fp, &partition).unwrap().unwrap();
        assert!(pr.shards_done > 0 && pr.shards_done < shards_total);
        let resume = ResumeState {
            shards_done: pr.shards_done,
            blocks_done: pr.blocks_done,
            acc,
        };
        let (resumed, _) =
            compress_source_opts(&gen, &mk(second), block, &comp, &opts, Some(resume), None);
        assert_eq!(
            resumed, reference,
            "resume {} → {} must be bitwise invisible",
            first.as_str(),
            second.as_str()
        );
        checkpoint::clear(&dir).unwrap();
    }
}

fn tier_cfg(tier: MapTierChoice, budget: usize) -> PipelineConfig {
    let mut b = PipelineConfig::builder()
        .reduced_dims(10, 10, 10)
        .rank(3)
        .anchor_rows(5)
        // Pinned block: the budgeted estimate must fit without shrinking in
        // *either* tier, so both tiers resolve the identical block grid.
        .block([8, 8, 8])
        .corner(12)
        .als(150, 1e-11)
        .threads(2)
        .map_tier(tier)
        .seed(71);
    if budget > 0 {
        b = b.memory_budget(budget);
    }
    b.build().unwrap()
}

fn run_tier(tier: MapTierChoice, budget: usize) -> PipelineResult {
    let gen = LowRankGenerator::new(64, 64, 64, 3, 700);
    Pipeline::new(tier_cfg(tier, budget)).run(&gen).unwrap()
}

/// The ISSUE 5 acceptance criterion: a budgeted (out-of-core) end-to-end
/// run in the procedural tier produces factors bitwise identical to the
/// materialized tier — and the auto tier, which resolves to procedural at
/// this budget, matches too.
#[test]
fn budgeted_pipeline_factors_bitwise_identical_across_tiers() {
    // 64³ f32 = 1 MiB tensor, 700 KiB budget → out-of-core plan.
    let budget = 700 << 10;
    let mat = run_tier(MapTierChoice::Materialized, budget);
    let proc_ = run_tier(MapTierChoice::Procedural, budget);
    assert!(mat.plan.out_of_core, "budget below tensor bytes must go out-of-core");
    assert_eq!(mat.plan.map_tier, MapTier::Materialized);
    assert_eq!(proc_.plan.map_tier, MapTier::Procedural);
    assert_eq!(mat.plan.block, proc_.plan.block, "tiers must resolve one block grid");
    assert_models_bitwise(&mat.model, &proc_.model, "budgeted pipeline");
    assert!(
        proc_.plan.estimated_bytes < mat.plan.estimated_bytes,
        "procedural plan must be cheaper ({} vs {})",
        proc_.plan.estimated_bytes,
        mat.plan.estimated_bytes
    );
    assert!(mat.diagnostics.rel_error < 0.05, "rel {}", mat.diagnostics.rel_error);

    // Auto at this budget resolves procedural (maps > budget/8) and stays
    // bitwise identical.
    let auto = run_tier(MapTierChoice::Auto, budget);
    assert_eq!(auto.plan.map_tier, MapTier::Procedural);
    assert_models_bitwise(&auto.model, &mat.model, "auto tier");
}

/// Unbudgeted runs agree too (auto resolves materialized there).
#[test]
fn unbudgeted_pipeline_factors_bitwise_identical_across_tiers() {
    let mat = run_tier(MapTierChoice::Materialized, 0);
    let proc_ = run_tier(MapTierChoice::Procedural, 0);
    let auto = run_tier(MapTierChoice::Auto, 0);
    assert_eq!(auto.plan.map_tier, MapTier::Materialized);
    assert_models_bitwise(&mat.model, &proc_.model, "unbudgeted pipeline");
    assert_models_bitwise(&mat.model, &auto.model, "auto tier (unbudgeted)");
}

/// A full-pipeline checkpoint written under one tier resumes under the
/// other: proxies are tier-independent, and the fingerprint ignores the
/// tier knob.
#[test]
fn pipeline_checkpoint_crosses_tiers() {
    let gen = LowRankGenerator::new(64, 64, 64, 3, 700);
    let dir = tmppath("ckpt_cross");
    let mut cfg_mat = tier_cfg(MapTierChoice::Materialized, 0);
    cfg_mat.checkpoint_dir = Some(dir.clone());
    let mut pipe = Pipeline::new(cfg_mat);
    let clean = pipe.run(&gen).unwrap();

    let mut cfg_proc = tier_cfg(MapTierChoice::Procedural, 0);
    cfg_proc.checkpoint_dir = Some(dir.clone());
    let mut pipe2 = Pipeline::new(cfg_proc);
    let resumed = pipe2.run(&gen).unwrap();
    assert!(
        pipe2.metrics.counter("checkpoint_resumed") > 0,
        "second run must resume the first run's proxies"
    );
    assert_models_bitwise(&clean.model, &resumed.model, "cross-tier checkpoint resume");
    checkpoint::clear(&dir).unwrap();
}

/// Replica drop (subset) composes with both tiers: recovery over a subset
/// is bitwise tier-invariant too.  Exercised through the whole pipeline by
/// the tests above; here the narrow algebra path is pinned with an exact
/// subset so a regression localizes.
#[test]
fn subset_recovery_is_tier_invariant() {
    use exascale_tensor::coordinator::recovery::stacked_recover;
    use exascale_tensor::linalg::{matmul, Matrix, Trans};
    use exascale_tensor::util::rng::Xoshiro256;
    let dims = [40, 30, 20];
    let mut rng = Xoshiro256::seed_from_u64(720);
    let truth = CpModel::new(
        Matrix::random_normal(dims[0], 2, &mut rng),
        Matrix::random_normal(dims[1], 2, &mut rng),
        Matrix::random_normal(dims[2], 2, &mut rng),
    );
    // Kept-stack column rank: S + 7·(L−S) = 3 + 7·6 = 45 ≥ 40.
    let mk = |tier| MapSource::generate(dims, [9, 9, 9], 9, 3, 721, tier);
    let keep = [0usize, 2, 3, 5, 6, 7, 8];
    let models = |maps: &MapSource| -> Vec<CpModel> {
        keep.iter()
            .map(|&p| {
                let u = maps.panel(p, 0, 0, dims[0], Vec::new());
                let v = maps.panel(p, 1, 0, dims[1], Vec::new());
                let w = maps.panel(p, 2, 0, dims[2], Vec::new());
                CpModel::new(
                    matmul(&u, Trans::No, &truth.a, Trans::No),
                    matmul(&v, Trans::No, &truth.b, Trans::No),
                    matmul(&w, Trans::No, &truth.c, Trans::No),
                )
            })
            .collect()
    };
    let mat = mk(MapTier::Materialized);
    let proc_ = mk(MapTier::Procedural);
    let rec_mat = stacked_recover(&models(&mat), &mat.subset(&keep)).unwrap();
    let rec_proc = stacked_recover(&models(&proc_), &proc_.subset(&keep)).unwrap();
    assert_models_bitwise(&rec_mat, &rec_proc, "subset recovery");
    // And it actually recovers the planted factors (sanity, not bitwise).
    assert!(rec_mat.a.rel_error(&truth.a) < 1e-3);
}
