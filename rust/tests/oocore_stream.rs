//! Integration tests for the out-of-core streaming engine: file-backed
//! sources vs in-memory, budgeted (out-of-core) pipeline runs, and
//! kill/resume invariance of incremental compression checkpoints.

use exascale_tensor::compress::{
    compress_source_batched_opts, compress_source_opts, MapSource, MapTier, PrefetchConfig,
    ResumeState, RustCompressor, StreamOptions,
};
use exascale_tensor::coordinator::checkpoint::{self, CompressionProgress};
use exascale_tensor::coordinator::{MemoryPlanner, Pipeline, PipelineConfig};
use exascale_tensor::cp::CpModel;
use exascale_tensor::mixed::MixedPrecision;
use exascale_tensor::tensor::{
    save_tensor_streamed, BlockSpec3, DenseTensor, FileTensorSource, InMemorySource,
    LowRankGenerator,
};
use exascale_tensor::util::threadpool::ThreadPool;

fn tmppath(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("exatensor_oocore_{name}_{}", std::process::id()));
    p
}

fn factors_rel_error(a: &CpModel, b: &CpModel) -> f64 {
    a.a.rel_error(&b.a).max(a.b.rel_error(&b.b)).max(a.c.rel_error(&b.c))
}

#[test]
fn file_source_pipeline_matches_in_memory() {
    let gen = LowRankGenerator::new(48, 48, 48, 3, 900);
    let path = tmppath("file_vs_mem.ext1");
    save_tensor_streamed(&gen, &path, 6).unwrap();
    let file_src = FileTensorSource::open(&path).unwrap();
    let tensor = exascale_tensor::tensor::io::load_tensor(&path).unwrap();
    let mem_src = InMemorySource::new(tensor);

    let cfg = || {
        PipelineConfig::builder()
            .reduced_dims(12, 12, 12)
            .rank(3)
            .block([16, 16, 16])
            .als(150, 1e-11)
            .threads(3)
            .seed(901)
            .build()
            .unwrap()
    };
    let from_file = Pipeline::new(cfg()).run(&file_src).unwrap();
    let from_mem = Pipeline::new(cfg()).run(&mem_src).unwrap();
    // Identical block data + deterministic engine ⇒ identical factors.
    let err = factors_rel_error(&from_file.model, &from_mem.model);
    assert!(err < 1e-6, "file vs in-memory factor err {err}");
    assert!(
        from_file.diagnostics.rel_error < 2e-2,
        "rel {}",
        from_file.diagnostics.rel_error
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn out_of_core_budgeted_run_succeeds_under_budget() {
    let gen = LowRankGenerator::new(64, 64, 64, 2, 902);
    let path = tmppath("oocore_budget.ext1");
    save_tensor_streamed(&gen, &path, 8).unwrap();
    let src = FileTensorSource::open(&path).unwrap();
    let tensor_bytes = src.payload_bytes();
    // Strictly below the tensor itself, but above the plan's floor — which
    // since PR 4 includes the replica-map bytes P·(L·I+M·J+N·K)·4 (~150 KiB
    // here), so 70% of the 1 MiB tensor no longer fits the minimum plan.
    let budget = tensor_bytes * 85 / 100;

    let cfg = PipelineConfig::builder()
        .reduced_dims(12, 12, 12)
        .rank(2)
        .als(150, 1e-11)
        .threads(2)
        .memory_budget(budget)
        .seed(903)
        .build()
        .unwrap();
    let mut pipe = Pipeline::new(cfg);
    let res = pipe.run(&src).unwrap();
    assert!(res.plan.out_of_core, "budget {budget} < tensor {tensor_bytes} must go out-of-core");
    assert!(res.plan.prefetch_depth >= 1, "out-of-core defaults prefetch on");
    assert!(res.plan.estimated_bytes <= budget);
    assert!(
        res.diagnostics.rel_error < 2e-2,
        "rel {}",
        res.diagnostics.rel_error
    );
    assert!(pipe.metrics.counter("blocks_streamed") > 0);
    assert!(pipe.metrics.stage("compress_io").is_some(), "I/O time must be surfaced");
    std::fs::remove_file(&path).ok();
}

/// Kill/resume at the streaming-engine + checkpoint layer: abort after the
/// first incremental save, resume from the loaded partial, and require the
/// final proxies to be bitwise identical to an uninterrupted pass.
#[test]
fn compress_kill_resume_is_bitwise_invariant() {
    let gen = LowRankGenerator::new(24, 24, 24, 2, 904);
    let maps = MapSource::generate([24, 24, 24], [6, 6, 6], 3, 2, 905, MapTier::Materialized);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let block = [5, 5, 5];
    let opts = StreamOptions { threads: 2, ..Default::default() };
    let blocks_total = BlockSpec3::new([24, 24, 24], block).num_blocks();
    let shards_total = ThreadPool::partition(blocks_total, opts.shard_parts).len();

    let (reference, _) =
        compress_source_opts(&gen, &maps, block, &comp, &opts, None, None);

    let dir = tmppath("kill_resume_ckpt");
    let fp = checkpoint::Fingerprint {
        dims: [24, 24, 24],
        reduced: [6, 6, 6],
        rank: 2,
        replicas: 3,
        anchor_rows: 2,
        seed: 905,
        mixed_precision: false,
    };
    let partition = CompressionProgress {
        block,
        shard_parts: opts.shard_parts,
        shards_total,
        shards_done: 0,
        blocks_done: 0,
        blocks_total,
        path: "plain".to_string(),
        generation: 0,
    };

    // "Kill": persist the first folded prefix, then stop the pass.
    let saved = std::sync::atomic::AtomicBool::new(false);
    let sink = |acc: &Vec<DenseTensor>, shards_done: usize, blocks_done: usize| {
        if saved.swap(true, std::sync::atomic::Ordering::SeqCst) {
            return false;
        }
        let mut pr = partition.clone();
        pr.shards_done = shards_done;
        pr.blocks_done = blocks_done;
        checkpoint::save_partial(&dir, &fp, &pr, acc).unwrap();
        false
    };
    let (_, stats) =
        compress_source_opts(&gen, &maps, block, &comp, &opts, None, Some(&sink));
    assert!(stats.aborted);

    // Resume from disk; the folded prefix must not be re-read.
    let (pr, acc) = checkpoint::load_partial(&dir, &fp, &partition).unwrap().unwrap();
    assert!(pr.shards_done > 0 && pr.shards_done < shards_total);
    let resume = ResumeState {
        shards_done: pr.shards_done,
        blocks_done: pr.blocks_done,
        acc,
    };
    let (resumed, stats2) =
        compress_source_opts(&gen, &maps, block, &comp, &opts, Some(resume), None);
    assert!(!stats2.aborted);
    assert_eq!(
        stats2.blocks_read as usize,
        blocks_total - pr.blocks_done,
        "resume must skip the folded prefix"
    );
    assert_eq!(resumed, reference, "kill/resume must be bitwise invisible");
    checkpoint::clear(&dir).unwrap();
}

/// Full-pipeline resume: a partial checkpoint authored mid-compression is
/// picked up by `Pipeline::run`, and the resumed run's factors match a
/// clean run exactly.
#[test]
fn pipeline_resumes_partial_checkpoint() {
    let gen = LowRankGenerator::new(32, 32, 32, 2, 906);
    let dims = [32, 32, 32];
    let cfg = |ckpt: Option<std::path::PathBuf>| {
        let mut b = PipelineConfig::builder()
            .reduced_dims(8, 8, 8)
            .rank(2)
            .anchor_rows(4)
            .block([8, 8, 8])
            .als(150, 1e-11)
            .threads(2)
            .seed(907);
        if let Some(d) = ckpt {
            b = b.checkpoint_dir(d);
        }
        b.build().unwrap()
    };
    let clean = Pipeline::new(cfg(None)).run(&gen).unwrap();

    // Author a partial checkpoint exactly as the pipeline would: same
    // plan, maps, fingerprint, and (batched) path.
    let dir = tmppath("pipeline_partial");
    let base = cfg(None);
    let plan = MemoryPlanner::plan(&base, dims).unwrap();
    let maps = MapSource::generate(
        dims,
        base.reduced,
        plan.replicas,
        base.effective_anchor(),
        base.seed,
        plan.map_tier,
    );
    let fp = checkpoint::default_fingerprint(&base, dims, plan.replicas);
    let opts = StreamOptions { threads: 2, ..Default::default() };
    let blocks_total = BlockSpec3::new(dims, plan.block).num_blocks();
    let shards_total = ThreadPool::partition(blocks_total, opts.shard_parts).len();
    let partition = CompressionProgress {
        block: plan.block,
        shard_parts: opts.shard_parts,
        shards_total,
        shards_done: 0,
        blocks_done: 0,
        blocks_total,
        path: "batched".to_string(),
        generation: 0,
    };
    let saved = std::sync::atomic::AtomicBool::new(false);
    let sink = |acc: &Vec<DenseTensor>, shards_done: usize, blocks_done: usize| {
        if saved.swap(true, std::sync::atomic::Ordering::SeqCst) {
            return false;
        }
        let mut pr = partition.clone();
        pr.shards_done = shards_done;
        pr.blocks_done = blocks_done;
        checkpoint::save_partial(&dir, &fp, &pr, acc).unwrap();
        false
    };
    let (_, stats) =
        compress_source_batched_opts(&gen, &maps, plan.block, &opts, None, Some(&sink));
    assert!(stats.aborted, "partial checkpoint must capture an incomplete pass");

    let mut pipe = Pipeline::new(cfg(Some(dir.clone())));
    let resumed = pipe.run(&gen).unwrap();
    assert!(
        pipe.metrics.counter("checkpoint_partial_resumed_blocks") > 0,
        "pipeline must resume from the partial checkpoint"
    );
    let err = factors_rel_error(&clean.model, &resumed.model);
    assert!(err < 1e-6, "resumed vs clean factor err {err}");
    checkpoint::clear(&dir).unwrap();
}

/// The same engine schedule invariance, exercised on a *file-backed*
/// source: prefetched out-of-core reads must be bitwise identical to
/// synchronous in-memory streaming.
#[test]
fn file_backed_prefetch_bitwise_matches_sync() {
    let gen = LowRankGenerator::new(20, 20, 20, 2, 908);
    let path = tmppath("prefetch_file.ext1");
    save_tensor_streamed(&gen, &path, 4).unwrap();
    let fsrc = FileTensorSource::open(&path).unwrap();
    let msrc = InMemorySource::new(exascale_tensor::tensor::io::load_tensor(&path).unwrap());

    let maps = MapSource::generate([20, 20, 20], [6, 6, 6], 2, 2, 909, MapTier::Materialized);
    let comp = RustCompressor { precision: MixedPrecision::Full };
    let sync_mem = compress_source_opts(
        &msrc,
        &maps,
        [7, 6, 5],
        &comp,
        &StreamOptions { threads: 2, ..Default::default() },
        None,
        None,
    )
    .0;
    let (pref_file, stats) = compress_source_opts(
        &fsrc,
        &maps,
        [7, 6, 5],
        &comp,
        &StreamOptions {
            threads: 4,
            prefetch: Some(PrefetchConfig { depth: 3, io_threads: 2 }),
            ..Default::default()
        },
        None,
        None,
    );
    assert!(stats.prefetched);
    assert!(stats.io_seconds > 0.0);
    assert_eq!(sync_mem, pref_file);
    std::fs::remove_file(&path).ok();
}
