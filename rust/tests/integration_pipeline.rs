//! Integration tests: the full Alg. 2 pipeline across backends, scales,
//! noise, sparsity, and failure injection.

use exascale_tensor::coordinator::{Backend, Pipeline, PipelineConfig, SensingConfig};
use exascale_tensor::cp::{model_congruence, CpModel};
use exascale_tensor::tensor::{
    BlockRange, DenseTensor, InMemorySource, LowRankGenerator, SparseLowRankGenerator,
    TensorSource,
};

fn base_cfg(reduced: usize, rank: usize) -> exascale_tensor::coordinator::PipelineConfigBuilder {
    PipelineConfig::builder()
        .reduced_dims(reduced, reduced, reduced)
        .rank(rank)
        .block([24, 24, 24])
        .als(150, 1e-11)
        .threads(4)
        .seed(5)
}

fn truth_of(gen: &LowRankGenerator) -> CpModel {
    let (a, b, c) = gen.factors.clone();
    CpModel::new(a, b, c)
}

#[test]
fn recovers_rank3_at_64() {
    let gen = LowRankGenerator::new(64, 64, 64, 3, 42);
    let cfg = base_cfg(12, 3).build().unwrap();
    let res = Pipeline::new(cfg).run(&gen).unwrap();
    assert!(res.diagnostics.rel_error < 1e-2, "rel {}", res.diagnostics.rel_error);
    assert!(model_congruence(&truth_of(&gen), &res.model) > 0.99);
}

#[test]
fn non_cubic_tensor() {
    let gen = LowRankGenerator::new(80, 40, 56, 3, 43);
    let cfg = PipelineConfig::builder()
        .reduced_dims(14, 10, 12)
        .rank(3)
        .block([30, 20, 25])
        .als(150, 1e-11)
        .seed(6)
        .build()
        .unwrap();
    let res = Pipeline::new(cfg).run(&gen).unwrap();
    assert!(res.diagnostics.rel_error < 2e-2, "rel {}", res.diagnostics.rel_error);
}

#[test]
fn rank_one_tensor() {
    let gen = LowRankGenerator::new(48, 48, 48, 1, 44);
    let cfg = base_cfg(8, 1).anchor_rows(4).build().unwrap();
    let res = Pipeline::new(cfg).run(&gen).unwrap();
    assert!(res.diagnostics.rel_error < 1e-2);
}

#[test]
fn sequential_and_parallel_agree() {
    let gen = LowRankGenerator::new(48, 48, 48, 2, 45);
    let seq = Pipeline::new(base_cfg(10, 2).backend(Backend::RustSequential).build().unwrap())
        .run(&gen)
        .unwrap();
    let par = Pipeline::new(base_cfg(10, 2).backend(Backend::RustParallel).build().unwrap())
        .run(&gen)
        .unwrap();
    let t_seq = seq.model.to_tensor();
    let t_par = par.model.to_tensor();
    assert!(t_seq.rel_error(&t_par) < 1e-3, "{}", t_seq.rel_error(&t_par));
}

#[test]
fn in_memory_source_matches_generator() {
    // Same underlying tensor via generator vs materialized: same answer.
    let gen = LowRankGenerator::new(40, 40, 40, 2, 46);
    let full = gen.block(&BlockRange { i0: 0, i1: 40, j0: 0, j1: 40, k0: 0, k1: 40, index: 0 });
    let mem = InMemorySource::new(full);
    let r1 = Pipeline::new(base_cfg(10, 2).build().unwrap()).run(&gen).unwrap();
    let r2 = Pipeline::new(base_cfg(10, 2).build().unwrap()).run(&mem).unwrap();
    // Parallel block accumulation commits in worker-completion order, so
    // runs are FP-equal only up to reduction reordering; both must land on
    // the same model to ~1e-2.
    assert!(r1.model.to_tensor().rel_error(&r2.model.to_tensor()) < 1e-2);
    assert!(r1.diagnostics.rel_error < 1e-2 && r2.diagnostics.rel_error < 1e-2);
}

#[test]
fn noise_degrades_gracefully() {
    let clean = LowRankGenerator::new(48, 48, 48, 2, 47);
    let noisy = LowRankGenerator::new(48, 48, 48, 2, 47).with_noise(1e-2);
    let rc = Pipeline::new(base_cfg(10, 2).build().unwrap()).run(&clean).unwrap();
    let rn = Pipeline::new(base_cfg(10, 2).build().unwrap()).run(&noisy).unwrap();
    assert!(rc.diagnostics.rel_error < rn.diagnostics.rel_error);
    assert!(rn.diagnostics.rel_error < 0.1, "noisy rel {}", rn.diagnostics.rel_error);
}

#[test]
fn mixed_precision_error_bounded() {
    let gen = LowRankGenerator::new(48, 48, 48, 2, 48);
    let full = Pipeline::new(base_cfg(10, 2).build().unwrap()).run(&gen).unwrap();
    let mixed = Pipeline::new(base_cfg(10, 2).mixed_precision(true).build().unwrap())
        .run(&gen)
        .unwrap();
    // bf16 split compression stays in the few-percent band; f32 is better.
    assert!(mixed.diagnostics.rel_error < 0.05);
    assert!(full.diagnostics.rel_error <= mixed.diagnostics.rel_error + 1e-3);
}

#[test]
fn sensing_on_sparse_tensor() {
    let gen = SparseLowRankGenerator::new(60, 60, 60, 2, 8, 49);
    let cfg = base_cfg(15, 2)
        .sensing(SensingConfig {
            alpha: 2.2,
            nnz_per_col: 12,
            lambda: 0.02,
        })
        .build()
        .unwrap();
    let res = Pipeline::new(cfg).run(&gen).unwrap();
    assert!(res.diagnostics.rel_error < 0.25, "rel {}", res.diagnostics.rel_error);
}

#[test]
fn memory_budget_respected() {
    let gen = LowRankGenerator::new(64, 64, 64, 2, 50);
    let budget = 64 * 1024 * 1024;
    let cfg = base_cfg(10, 2).memory_budget(budget).build().unwrap();
    let res = Pipeline::new(cfg).run(&gen).unwrap();
    assert!(res.plan.estimated_bytes <= budget);
    assert!(res.diagnostics.rel_error < 2e-2);
}

#[test]
fn impossible_budget_fails_cleanly() {
    let gen = LowRankGenerator::new(64, 64, 64, 2, 51);
    let cfg = base_cfg(10, 2).memory_budget(1024).build().unwrap();
    assert!(Pipeline::new(cfg).run(&gen).is_err());
}

#[test]
fn reduced_dims_larger_than_tensor_rejected() {
    let gen = LowRankGenerator::new(8, 8, 8, 2, 52);
    let cfg = base_cfg(10, 2).build().unwrap(); // reduced 10 > dims 8
    assert!(Pipeline::new(cfg).run(&gen).is_err());
}

#[test]
fn metrics_cover_every_stage() {
    let gen = LowRankGenerator::new(40, 40, 40, 2, 53);
    let mut pipe = Pipeline::new(base_cfg(10, 2).build().unwrap());
    pipe.run(&gen).unwrap();
    for stage in ["compress", "decompose", "align", "stacked_lstsq", "disambiguate"] {
        assert!(pipe.metrics.stage(stage).is_some(), "missing {stage}");
    }
    assert!(pipe.metrics.counter("replicas") > 0);
}

/// Failure injection: a tensor source with a corrupted spike entry; the
/// pipeline should still land in the right ballpark (robustness comes from
/// the replica redundancy + fit-based drops).
struct SpikySource {
    inner: LowRankGenerator,
}

impl TensorSource for SpikySource {
    fn dims(&self) -> [usize; 3] {
        self.inner.dims()
    }

    fn block(&self, r: &BlockRange) -> DenseTensor {
        let mut t = self.inner.block(r);
        if r.i0 <= 30 && 30 < r.i1 && r.j0 <= 30 && 30 < r.j1 && r.k0 <= 30 && 30 < r.k1 {
            t.set(30 - r.i0, 30 - r.j0, 30 - r.k0, 20.0); // ~4% of the tensor norm
        }
        t
    }
}

#[test]
fn checkpoint_resume_skips_compression() {
    let gen = LowRankGenerator::new(40, 40, 40, 2, 55);
    let mut dir = std::env::temp_dir();
    dir.push(format!("exatensor_ckpt_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let cfg = base_cfg(10, 2).checkpoint_dir(dir.clone()).build().unwrap();
    let mut first = Pipeline::new(cfg.clone());
    let r1 = first.run(&gen).unwrap();
    assert!(first.metrics.stage("compress").is_some());
    assert!(dir.join("checkpoint.json").exists());

    // Second run resumes: no compression stage, same quality.
    let mut second = Pipeline::new(cfg);
    let r2 = second.run(&gen).unwrap();
    assert!(second.metrics.stage("compress").is_none(), "compression should be skipped");
    assert_eq!(second.metrics.counter("checkpoint_resumed"), 1);
    assert!(r2.diagnostics.rel_error < r1.diagnostics.rel_error + 1e-3);

    // A different seed must refuse to resume (fail loudly, not corrupt).
    let cfg_other = base_cfg(10, 2).checkpoint_dir(dir.clone()).seed(999).build().unwrap();
    assert!(Pipeline::new(cfg_other).run(&gen).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_corrupted_entry_is_survivable() {
    let src = SpikySource {
        inner: LowRankGenerator::new(48, 48, 48, 2, 54),
    };
    let cfg = base_cfg(10, 2).build().unwrap();
    let res = Pipeline::new(cfg).run(&src).unwrap();
    assert!(
        res.diagnostics.rel_error < 0.2,
        "rel {}",
        res.diagnostics.rel_error
    );
}
